"""Cohort engine (DESIGN.md §14): O(S) participant-only sampling, keyed EF
store, virtual-population data view, and dense==cohort trajectory equality
for every sample-based driver — including the int8+EF+sharded composition.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import codecs, error_feedback as ef_lib
from repro.configs.base import FLConfig
from repro.core import algorithms, baselines, fed, local_updates
from repro.core import topology as topology_lib
from repro.data.synthetic import VirtualFedData
from repro.models import mlp

P, J, L = 10, 8, 3


def _fl(**kw):
    base = dict(batch_size=6, a1=0.9, a2=0.5, alpha_rho=0.1,
                alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5)
    base.update(kw)
    return FLConfig(**base)


def _virtual(key, num_clients, **kw):
    kw.setdefault("n_min", 6)
    kw.setdefault("n_max", 14)
    kw.setdefault("num_features", P)
    kw.setdefault("num_classes", L)
    return VirtualFedData(key, num_clients, **kw)


def _params(key):
    return mlp.init(key, P, J, L)


def _maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# cohort_sample: the keyed Feistel draw
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("num_clients,cohort", [(5, 2), (50, 10), (64, 64),
                                                (1000, 64), (1_000_000, 256)])
def test_cohort_sample_valid_draw(num_clients, cohort):
    ids = fed.cohort_sample(jax.random.PRNGKey(3), num_clients, cohort)
    assert ids.shape == (cohort,)
    assert ids.dtype == jnp.int32
    assert int(jnp.min(ids)) >= 0 and int(jnp.max(ids)) < num_clients
    # a Feistel permutation is a bijection: no duplicates, ever
    assert len(np.unique(np.asarray(ids))) == cohort


def test_cohort_sample_key_sensitivity():
    a = fed.cohort_sample(jax.random.PRNGKey(0), 10_000, 64)
    b = fed.cohort_sample(jax.random.PRNGKey(1), 10_000, 64)
    assert not np.array_equal(np.asarray(a), np.asarray(b))
    # and deterministic per key
    c = fed.cohort_sample(jax.random.PRNGKey(0), 10_000, 64)
    assert np.array_equal(np.asarray(a), np.asarray(c))


def test_cohort_sample_rejects_bad_cohort():
    with pytest.raises(ValueError, match="cohort"):
        fed.cohort_sample(jax.random.PRNGKey(0), 10, 11)
    with pytest.raises(ValueError, match="cohort"):
        fed.cohort_sample(jax.random.PRNGKey(0), 10, 0)


def test_cohort_sample_unbiased_selection_frequency():
    """Statistical unbiasedness: over R independent draws each client is
    selected with empirical frequency ≈ S/I, within a 5σ binomial bound."""
    num_clients, cohort, draws = 50, 10, 400
    keys = jax.random.split(jax.random.PRNGKey(7), draws)
    sel = jax.vmap(lambda k: fed.cohort_sample(k, num_clients, cohort))(keys)
    counts = np.bincount(np.asarray(sel).ravel(), minlength=num_clients)
    freq = counts / draws
    p = cohort / num_clients
    sigma = np.sqrt(p * (1 - p) / draws)
    assert abs(freq.mean() - p) < 1e-9          # exactly S picks per draw
    assert np.max(np.abs(freq - p)) < 5 * sigma, (freq.min(), freq.max())


def test_participation_mask_scatters_cohort_sample():
    """The dense mask and the O(S) draw select the SAME clients from the
    same key — the property every dense-vs-cohort equality test rests on."""
    key = jax.random.PRNGKey(5)
    ids = fed.cohort_sample(key, 40, 12)
    mask = fed.participation_mask(key, 40, 12)
    assert float(jnp.sum(mask)) == 12.0
    expect = jnp.zeros((40,)).at[ids].set(1.0)
    assert jnp.array_equal(mask, expect)


# ---------------------------------------------------------------------------
# keyed EF store
# ---------------------------------------------------------------------------


def test_ef_store_gather_scatter_roundtrip():
    store = ef_lib.ef_store_init(20, 4)
    ids = jnp.array([3, 7, 11], jnp.int32)
    rows = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
    new = store.scatter(ids, rows)
    assert jnp.array_equal(new.gather(ids), rows)
    # every non-cohort row is bit-frozen (still the zeros it started as)
    others = jnp.array([i for i in range(20) if i not in (3, 7, 11)])
    assert jnp.array_equal(new.gather(others), jnp.zeros((17, 4)))
    # the original store is unchanged (functional update)
    assert float(jnp.sum(jnp.abs(store.data))) == 0.0


def test_ef_store_matches_dense_ef_with_frozen_nonparticipants():
    """Gather/scatter EF round-trip == dense EF: participants' residuals
    identical, non-participants' rows bit-frozen in both layouts."""
    key = jax.random.PRNGKey(9)
    num_clients, dim, cohort = 16, 8, 5
    codec = codecs.make_codec("int8")
    ids = fed.cohort_sample(jax.random.fold_in(key, 1), num_clients, cohort)
    uploads = jax.random.normal(jax.random.fold_in(key, 2), (num_clients, dim))
    ckeys = fed.client_keys(jax.random.fold_in(key, 3),
                            jnp.arange(num_clients))
    pmask = fed.participation_mask(jax.random.fold_in(key, 1), num_clients,
                                   cohort)
    # dense: all clients run the roundtrip, active freezes non-participants
    dense0 = ef_lib.ef_init_stacked(num_clients, dim)
    _, _, dense1 = jax.vmap(
        lambda x, r, k, a: ef_lib.ef_roundtrip(codec, x, r, k, a)
    )(uploads, dense0, ckeys, pmask)
    # keyed: only the cohort's rows are gathered, updated, scattered
    store = ef_lib.ef_store_init(num_clients, dim)
    _, _, rows = jax.vmap(
        lambda x, r, k: ef_lib.ef_roundtrip(codec, x, r, k)
    )(uploads[ids], store.gather(ids), ckeys[ids])
    store1 = store.scatter(ids, rows)
    assert jnp.array_equal(dense1, store1.data)


def test_ef_store_host_offload_same_interface():
    a = ef_lib.ef_store_init(8, 3, host_offload=False)
    b = ef_lib.ef_store_init(8, 3, host_offload=True)
    ids = jnp.array([1, 6], jnp.int32)
    rows = jnp.ones((2, 3), jnp.float32)
    assert jnp.array_equal(a.scatter(ids, rows).data,
                           b.scatter(ids, rows).data)


# ---------------------------------------------------------------------------
# virtual population == materialized dense container
# ---------------------------------------------------------------------------


def test_virtual_data_matches_materialized():
    vd = _virtual(jax.random.PRNGKey(11), 48)
    dense = vd.materialize()
    assert vd.total == int(dense.total)
    ids = jnp.array([0, 17, 47, 3], jnp.int32)
    assert jnp.array_equal(vd.counts_for(ids), dense.counts_for(ids))
    idx = jnp.array([[0, 1, 2], [3, 0, 1], [2, 2, 2], [1, 0, 4]], jnp.int32)
    zv, yv = vd.batch_rows(ids, idx)
    zd, yd = dense.batch_rows(ids, idx)
    assert jnp.array_equal(zv, zd) and jnp.array_equal(yv, yd)
    for a, b in zip(vd.shards_for(ids), dense.shards_for(ids)):
        assert jnp.array_equal(a, b)


def test_virtual_data_total_never_materializes_population():
    """Construction at I = 1e6 must be cheap (chunked total, no (I,) array)
    and materialize() must refuse."""
    vd = _virtual(jax.random.PRNGKey(1), 1_000_000)
    assert vd.total > 0
    with pytest.raises(ValueError, match="materialize"):
        vd.materialize()


def test_virtual_data_ragged_counts():
    vd = _virtual(jax.random.PRNGKey(2), 200, n_min=3, n_max=9)
    counts = np.asarray(vd.counts_for(jnp.arange(200)))
    assert counts.min() >= 3 and counts.max() <= 9
    assert len(np.unique(counts)) > 1          # genuinely ragged


# ---------------------------------------------------------------------------
# single-round equality: cohort_round == sample_round(participation=S)
# ---------------------------------------------------------------------------


def test_cohort_round_matches_sample_round_dense():
    key = jax.random.PRNGKey(21)
    vd = _virtual(jax.random.fold_in(key, 1), 40)
    dense = vd.materialize()
    params = _params(jax.random.fold_in(key, 2))
    rk = jax.random.fold_in(key, 3)
    gd, vd_, ud = fed.sample_round(mlp.per_sample_loss, params, dense, rk, 6,
                                   with_value=True, participation=10)
    gc, vc, uc = fed.cohort_round(mlp.per_sample_loss, params, vd, rk, 6, 10,
                                  with_value=True)
    assert _maxdiff(gd, gc) < 1e-5
    assert abs(float(vd_) - float(vc)) < 1e-5
    sel = jnp.sort(jnp.flatnonzero(ud["participants"]))
    assert jnp.array_equal(sel, jnp.sort(uc["cohort"]))


def test_cohort_round_uploads_scale_with_cohort_only():
    """O(S) invariant: everything the round materializes is (S, ...), never
    (I, ...) — except the EFStore backing, which lives outside the round."""
    vd = _virtual(jax.random.PRNGKey(4), 10_000)
    params = _params(jax.random.PRNGKey(5))
    codec = codecs.make_codec("int8")
    dim = codecs.tree_flat_dim(params)
    store = ef_lib.ef_store_init(10_000, dim)
    g, v, up = fed.cohort_round(mlp.per_sample_loss, params, vd,
                                jax.random.PRNGKey(6), 4, 32,
                                codec=codec, ef=store)
    assert up["cohort"].shape == (32,)
    for leaf in jax.tree.leaves(up["q_grad_sums"]):
        assert leaf.shape[0] == 32
    for leaf in jax.tree.leaves(up["encoded"]):
        assert leaf.shape[0] == 32
    assert up["ef"].data.shape == (10_000, dim)


def test_cohort_round_rejects_dense_ef():
    vd = _virtual(jax.random.PRNGKey(4), 30)
    params = _params(jax.random.PRNGKey(5))
    dense_ef = ef_lib.ef_init_stacked(30, codecs.tree_flat_dim(params))
    with pytest.raises(ValueError, match="EFStore"):
        fed.cohort_round(mlp.per_sample_loss, params, vd,
                         jax.random.PRNGKey(6), 4, 8,
                         codec=codecs.make_codec("int8"), ef=dense_ef)


def test_cohort_drivers_require_participation():
    vd = _virtual(jax.random.PRNGKey(4), 30)
    params = _params(jax.random.PRNGKey(5))
    with pytest.raises(ValueError, match="participation"):
        algorithms.algorithm1(mlp.per_sample_loss, params, vd, _fl(), 2,
                              jax.random.PRNGKey(0), cohort=True)


# ---------------------------------------------------------------------------
# trajectory equality: every sample-based driver, dense engine vs O(S) engine
# ---------------------------------------------------------------------------

I_TRAJ, S_TRAJ, K_TRAJ = 48, 12, 10


def _setup(seed=31):
    key = jax.random.PRNGKey(seed)
    vd = _virtual(jax.random.fold_in(key, 1), I_TRAJ)
    return (vd, vd.materialize(), _params(jax.random.fold_in(key, 2)),
            jax.random.fold_in(key, 3))


def test_trajectory_algorithm1_dense_vs_cohort():
    vd, dense, params0, rk = _setup()
    rd = algorithms.algorithm1(mlp.per_sample_loss, params0, dense, _fl(),
                               K_TRAJ, rk, participation=S_TRAJ)
    rc = algorithms.algorithm1(mlp.per_sample_loss, params0, vd, _fl(),
                               K_TRAJ, rk, participation=S_TRAJ, cohort=True)
    assert _maxdiff(rd.params, rc.params) < 1e-5


def test_trajectory_algorithm1_int8_ef_dense_vs_cohort():
    vd, dense, params0, rk = _setup()
    codec = codecs.make_codec("int8")
    rd = algorithms.algorithm1(mlp.per_sample_loss, params0, dense, _fl(),
                               K_TRAJ, rk, participation=S_TRAJ, codec=codec)
    rc = algorithms.algorithm1(mlp.per_sample_loss, params0, vd, _fl(),
                               K_TRAJ, rk, participation=S_TRAJ, codec=codec,
                               cohort=True)
    assert _maxdiff(rd.params, rc.params) < 1e-5
    # the EF layouts track each other (bit-equality only holds for a single
    # round — see test_ef_store_matches_dense_ef_with_frozen_nonparticipants;
    # over K rounds the engines' iterates differ by float reassociation, so
    # the residuals inherit that tolerance)
    np.testing.assert_allclose(np.asarray(rd.final_state.ef),
                               np.asarray(rc.final_state.ef.data), atol=1e-5)


def test_trajectory_algorithm2_dense_vs_cohort():
    vd, dense, params0, rk = _setup()
    fl = _fl(constrained=True, cost_limit=1.2, penalty_c=1e4)
    codec = codecs.make_codec("int8")
    rd = algorithms.algorithm2(mlp.per_sample_loss, params0, dense, fl,
                               K_TRAJ, rk, participation=S_TRAJ, codec=codec)
    rc = algorithms.algorithm2(mlp.per_sample_loss, params0, vd, fl,
                               K_TRAJ, rk, participation=S_TRAJ, codec=codec,
                               cohort=True)
    assert _maxdiff(rd.params, rc.params) < 1e-5


def test_trajectory_algorithm2_general_dense_vs_cohort():
    vd, dense, params0, rk = _setup()
    fl = _fl(constrained=True, cost_limit=1.2, penalty_c=1e4)
    codec = codecs.make_codec("int8")
    rd = algorithms.algorithm2_general(mlp.per_sample_loss,
                                       mlp.per_sample_loss, params0, dense,
                                       fl, K_TRAJ, rk, participation=S_TRAJ,
                                       codec=codec)
    rc = algorithms.algorithm2_general(mlp.per_sample_loss,
                                       mlp.per_sample_loss, params0, vd,
                                       fl, K_TRAJ, rk, participation=S_TRAJ,
                                       codec=codec, cohort=True)
    assert _maxdiff(rd.params, rc.params) < 1e-5
    for stream in ("obj", "cons"):
        np.testing.assert_allclose(
            np.asarray(rd.final_state.ef[stream]),
            np.asarray(rc.final_state.ef[stream].data), atol=1e-5)


def test_trajectory_sample_sgd_dense_vs_cohort():
    vd, dense, params0, rk = _setup()
    cfg = baselines.SGDConfig(local_steps=2, local_batch=4)
    codec = codecs.make_codec("int8")
    rd = baselines.sample_sgd(mlp.per_sample_loss, params0, dense, cfg,
                              K_TRAJ, rk, participation=S_TRAJ, codec=codec)
    rc = baselines.sample_sgd(mlp.per_sample_loss, params0, vd, cfg,
                              K_TRAJ, rk, participation=S_TRAJ, codec=codec,
                              cohort=True)
    assert _maxdiff(rd.params, rc.params) < 1e-5


def test_trajectory_algorithm1_local_dense_vs_cohort():
    vd, dense, params0, rk = _setup()
    rd = local_updates.algorithm1_local(mlp.per_sample_loss, params0, dense,
                                        _fl(), K_TRAJ, rk, local_steps=2,
                                        participation=S_TRAJ)
    rc = local_updates.algorithm1_local(mlp.per_sample_loss, params0, vd,
                                        _fl(), K_TRAJ, rk, local_steps=2,
                                        participation=S_TRAJ, cohort=True)
    assert _maxdiff(rd.params, rc.params) < 1e-5


def test_trajectory_cohort_sharded_matches_local():
    """The sharded topology splits the COHORT: trajectory equal to the local
    cohort engine (a 1-device mesh still runs shard_map + psum)."""
    vd, _, params0, rk = _setup()
    codec = codecs.make_codec("int8")
    topo = topology_lib.sharded_for(S_TRAJ)
    rl = algorithms.algorithm1(mlp.per_sample_loss, params0, vd, _fl(),
                               K_TRAJ, rk, participation=S_TRAJ, codec=codec,
                               cohort=True)
    rs = algorithms.algorithm1(mlp.per_sample_loss, params0, vd, _fl(),
                               K_TRAJ, rk, participation=S_TRAJ, codec=codec,
                               cohort=True, topology=topo)
    assert _maxdiff(rl.params, rs.params) < 1e-5
    assert jnp.array_equal(rl.final_state.ef.data, rs.final_state.ef.data)

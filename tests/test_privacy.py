"""DP layer invariants (DESIGN.md §15): the analytic Gaussian calibration,
the subsampled-RDP cross-round accountant, and the clip→noise stage composed
with the full risk surface — DP × {identity, int8+EF} × {local, sharded} ×
{full, S-of-I, cohort}.

Pinned here:
* calibration — the analytic σ achieves the exact Balle-Wang δ, is never
  looser than the classical sqrt(2 ln(1.25/δ))/ε closed form, and the
  classical form remains a valid (if loose) calibration in its ε < 1 regime;
* accounting — the streamed dp_epsilon comes from the subsampled-RDP
  accountant (hand-computed 2-round case recomputed independently in the
  test, binomial-sum RDP recomputation for q < 1), composes monotonically
  over K rounds, and shows subsampling amplification;
* composition — dp=None is bitwise-identical to the pre-DP path; with DP on
  and fixed noise keys, dense == cohort and local == sharded trajectories
  agree at atol 1e-5, with and without int8+EF; the noised aggregate is
  unbiased (5σ over averaged rounds); the clip-fraction metric matches a
  from-scratch per-client norm computation;
* the deprecated privacy.dp_sample_round shim warns and delegates;
* checkpoint dtype safety (the satellite fix): load_checkpoint raises on a
  dtype mismatch unless cast=True.

On a single-device run the sharded cases degenerate to one shard but still
exercise the shard_map + psum path; the multi-device CI job re-runs this
file with 8 virtual devices (real per-shard noise before the psum).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import ef_init, ef_init_stacked, make_codec
from repro.comm.codecs import tree_flat_dim
from repro.configs.base import FLConfig
from repro.core import algorithms, fed, privacy
from repro.core.topology import feature_sharded_for, sharded_for
from repro.models import mlp

P, J, L = 12, 6, 3
I = 8                                  # client count; divisible by 1/2/4/8
B = 20
S = 4                                  # cohort size for partial participation
DELTA = 1e-5


def _data(key, n=240):
    z = jax.random.normal(key, (n, P))
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, L)
    return fed.partition_samples(z, jax.nn.one_hot(lab, L), I)


def _params0(key):
    return mlp.init(key, P, J, L)


def _fl(**kw):
    base = dict(batch_size=B, a1=0.9, a2=0.5, alpha_rho=0.1,
                alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5)
    base.update(kw)
    return FLConfig(**base)


def _assert_trees_close(a, b, **kw):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


psl = mlp.per_sample_loss


# ---------------------------------------------------------------------------
# calibration: analytic Gaussian mechanism vs the classical closed form
# ---------------------------------------------------------------------------


EPS_GRID = (0.1, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0)


def test_analytic_sigma_achieves_delta_exactly():
    for eps in EPS_GRID:
        sigma = privacy.analytic_gaussian_sigma(eps, DELTA)
        d = privacy.gaussian_mechanism_delta(eps, sigma)
        # binary search converges to the boundary of the exact condition
        assert d <= DELTA
        assert d > 0.999 * DELTA, (eps, sigma, d)


def test_analytic_never_looser_than_classical():
    for eps in EPS_GRID:
        an = privacy.analytic_gaussian_sigma(eps, DELTA)
        cl = privacy.classical_noise_multiplier(eps, DELTA)
        assert an <= cl * (1 + 1e-12), (eps, an, cl)
    # where the classical form is OUT of its ε < 1 regime (the historical
    # default ε = 8) the analytic calibration is strictly tighter
    assert (privacy.analytic_gaussian_sigma(8.0, DELTA)
            < 0.9999 * privacy.classical_noise_multiplier(8.0, DELTA))


def test_classical_bound_recovered_for_small_eps():
    # in its validity regime ε < 1 the classical σ satisfies the exact
    # condition — the analytic mechanism reduces to (tightens) it rather
    # than contradicting it
    for eps in (0.1, 0.25, 0.5):
        cl = privacy.classical_noise_multiplier(eps, DELTA)
        assert privacy.gaussian_mechanism_delta(eps, cl) <= DELTA


def test_noise_multiplier_override_and_validation():
    dp = privacy.DPConfig(epsilon=4.0, delta=DELTA, noise_multiplier=3.5)
    assert privacy.noise_multiplier(dp) == 3.5
    dp2 = privacy.DPConfig(epsilon=4.0, delta=DELTA)
    assert privacy.noise_multiplier(dp2) == pytest.approx(
        privacy.analytic_gaussian_sigma(4.0, DELTA))
    with pytest.raises(ValueError):
        privacy.analytic_gaussian_sigma(-1.0, DELTA)
    with pytest.raises(ValueError):
        privacy.analytic_gaussian_sigma(1.0, 2.0)


# ---------------------------------------------------------------------------
# accountant: subsampled-Gaussian RDP, composed over rounds
# ---------------------------------------------------------------------------


def test_accountant_matches_hand_computed_two_round_case():
    # q = 1, σ = 2, K = 2: RDP(α) = α/(2σ²) per release, composes to
    # 2α/(2σ²); ε = min_α [2α/(2σ²) + ln(1/δ)/(α−1)] — recomputed from
    # scratch here with a plain python loop over the same orders
    sigma = 2.0
    hand = min(2.0 * a / (2.0 * sigma ** 2)
               + math.log(1.0 / DELTA) / (a - 1)
               for a in privacy.DEFAULT_ORDERS)
    got = privacy.accountant_epsilon(sigma, 1.0, 2, DELTA)
    assert got == pytest.approx(hand, rel=1e-12)


def test_subsampled_rdp_matches_binomial_recomputation():
    # q < 1 integer-α bound recomputed directly with math.comb (no
    # log-space tricks) at small α / moderate σ where it cannot overflow
    q, sigma = 0.25, 2.0
    rdp = privacy.rdp_per_round(q, sigma, orders=(2, 3, 8))
    for a, got in zip((2, 3, 8), rdp):
        s = sum(math.comb(a, k) * (1 - q) ** (a - k) * q ** k
                * math.exp(k * (k - 1) / (2.0 * sigma ** 2))
                for k in range(a + 1))
        assert got == pytest.approx(math.log(s) / (a - 1), rel=1e-10)


def test_epsilon_monotone_and_subsampling_amplification():
    dp = privacy.DPConfig(epsilon=2.0, delta=DELTA)
    sched = privacy.epsilon_schedule(dp, 1.0, 10)
    assert np.all(np.diff(sched) > 0)
    nm = privacy.noise_multiplier(dp)
    full = privacy.accountant_epsilon(nm, 1.0, 10, DELTA)
    sub = privacy.accountant_epsilon(nm, 0.25, 10, DELTA)
    assert sub < full / 2          # amplification by subsampling is real


def test_eps_fn_matches_host_schedule():
    dp = privacy.DPConfig(epsilon=4.0, delta=DELTA)
    eps_fn = privacy.make_eps_fn(dp, 0.5, releases_per_round=2)
    sched = privacy.epsilon_schedule(dp, 0.5, 6, releases_per_round=2)
    got = np.asarray([float(eps_fn(t)) for t in range(1, 7)])
    np.testing.assert_allclose(got, sched, rtol=1e-5)


def test_manifest_info_records_accountant():
    dp = privacy.DPConfig(clip_norm=2.0, epsilon=4.0, delta=DELTA)
    info = privacy.manifest_info(dp, 0.5, rounds=10)
    assert info["accountant"] == "subsampled-gaussian-rdp"
    assert info["clip_norm"] == 2.0
    assert info["epsilon_total"] == pytest.approx(privacy.accountant_epsilon(
        privacy.noise_multiplier(dp), 0.5, 10, DELTA))


# ---------------------------------------------------------------------------
# composition matrix: DP × codec/EF × topology × participation
# ---------------------------------------------------------------------------


def test_dp_none_round_is_unchanged():
    data = _data(jax.random.PRNGKey(0))
    params = _params0(jax.random.PRNGKey(1))
    g0, v0, up0 = fed.sample_round(psl, params, data, jax.random.PRNGKey(2),
                                   B)
    g1, v1, up1 = fed.sample_round(psl, params, data, jax.random.PRNGKey(2),
                                   B, dp=None)
    assert up0["dp"] is None and up1["dp"] is None
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dp_none_driver_trajectory_bitwise_unchanged():
    data = _data(jax.random.PRNGKey(0))
    fl = _fl()
    params0 = _params0(jax.random.PRNGKey(1))
    r0 = algorithms.algorithm1(psl, params0, data, fl, 4,
                               jax.random.PRNGKey(3))
    r1 = algorithms.algorithm1(psl, params0, data, fl, 4,
                               jax.random.PRNGKey(3), dp=None)
    for a, b in zip(jax.tree.leaves(r0.params), jax.tree.leaves(r1.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert "round_dp_epsilon" not in r0.history


def test_noised_aggregate_unbiased_5sigma():
    # loose clip (never binds) → dp aggregate − dense aggregate is exactly
    # the weighted noise Σ_i (N_i/N)·η_i, η_i ~ N(0, σ²C²I); averaging R
    # independent noise draws on the SAME batches shrinks it by sqrt(R)
    data = _data(jax.random.PRNGKey(0))
    params = _params0(jax.random.PRNGKey(1))
    dp = privacy.DPConfig(clip_norm=100.0, epsilon=8.0, delta=DELTA)
    rk = jax.random.PRNGKey(5)
    g_dense, _, _ = fed.sample_round(psl, params, data, rk, B)
    flat_dense = jnp.concatenate([x.ravel()
                                  for x in jax.tree.leaves(g_dense)])

    @jax.jit
    def one(dk):
        g, _, _ = fed.sample_round(psl, params, data, rk, B, dp=dp, dp_key=dk)
        return jnp.concatenate([x.ravel() for x in jax.tree.leaves(g)])

    R = 64
    acc = jnp.zeros_like(flat_dense)
    for r in range(R):
        acc = acc + one(jax.random.fold_in(jax.random.PRNGKey(9), r))
    diff = acc / R - flat_dense
    # per-coordinate std of the averaged aggregate noise
    sigma_agg = (privacy.noise_multiplier(dp) * dp.clip_norm
                 * math.sqrt(float(jnp.sum(
                     (data.counts / data.total) ** 2))) / math.sqrt(R))
    assert float(jnp.max(jnp.abs(diff))) < 5 * sigma_agg


@pytest.mark.parametrize("codec_name", [None, "int8"])
def test_dp_trajectory_dense_matches_cohort(codec_name):
    data = _data(jax.random.PRNGKey(0))
    fl = _fl()
    params0 = _params0(jax.random.PRNGKey(1))
    dp = privacy.DPConfig(clip_norm=5.0, epsilon=4.0, delta=DELTA)
    codec = make_codec(codec_name)
    kw = dict(participation=S, dp=dp, codec=codec)
    rd = algorithms.algorithm1(psl, params0, data, fl, 5,
                               jax.random.PRNGKey(3), **kw)
    rc = algorithms.algorithm1(psl, params0, data, fl, 5,
                               jax.random.PRNGKey(3), cohort=True, **kw)
    _assert_trees_close(rd.params, rc.params, atol=1e-5)
    np.testing.assert_allclose(np.asarray(rd.history["round_dp_epsilon"]),
                               np.asarray(rc.history["round_dp_epsilon"]),
                               rtol=1e-6)


@pytest.mark.parametrize("codec_name", [None, "int8"])
def test_dp_trajectory_local_matches_sharded(codec_name):
    data = _data(jax.random.PRNGKey(0))
    fl = _fl()
    params0 = _params0(jax.random.PRNGKey(1))
    dp = privacy.DPConfig(clip_norm=5.0, epsilon=4.0, delta=DELTA)
    kw = dict(dp=dp, codec=make_codec(codec_name))
    rl = algorithms.algorithm1(psl, params0, data, fl, 5,
                               jax.random.PRNGKey(3), **kw)
    rs = algorithms.algorithm1(psl, params0, data, fl, 5,
                               jax.random.PRNGKey(3),
                               topology=sharded_for(I), **kw)
    _assert_trees_close(rl.params, rs.params, atol=1e-5)


def test_dp_feature_round_local_matches_sharded():
    z = jax.random.normal(jax.random.PRNGKey(0), (240, 16))
    lab = jax.random.randint(jax.random.PRNGKey(1), (240,), 0, L)
    data = fed.partition_features(z, jax.nn.one_hot(lab, L), 4)
    params = {"w0": jax.random.normal(jax.random.PRNGKey(2), (L, J)) * 0.2,
              "blocks": jax.random.normal(jax.random.PRNGKey(3),
                                          (4, J, 4)) * 0.2}
    dp = privacy.DPConfig(clip_norm=2.0, epsilon=4.0, delta=DELTA)
    codec = make_codec("int8")
    ef = {"w0": ef_init(tree_flat_dim(params["w0"])),
          "blocks": ef_init_stacked(4, tree_flat_dim(params["blocks"],
                                                     stacked=True))}
    args = (params, data, jax.random.PRNGKey(4), B,
            mlp.per_sample_loss_from_h, mlp.client_h)
    gl, _, upl = fed.feature_round(*args, codec=codec, ef=ef, dp=dp)
    gs, _, ups = fed.feature_round(*args, codec=codec, ef=ef, dp=dp,
                                   topology=feature_sharded_for(4))
    _assert_trees_close(gl, gs, atol=1e-5)
    for k in ("head_clipped", "blocks_clipped"):
        np.testing.assert_allclose(np.asarray(upl["dp"][k]),
                                   np.asarray(ups["dp"][k]))


def test_clip_fraction_metric_matches_from_scratch_norms():
    data = _data(jax.random.PRNGKey(0))
    params = _params0(jax.random.PRNGKey(1))
    rk = jax.random.PRNGKey(6)
    # per-client mean-gradient norms from the UN-noised round
    _, _, up0 = fed.sample_round(psl, params, data, rk, B)
    sums = up0["q_grad_sums"]           # stacked per-client q pytree
    flat = jnp.concatenate(
        [x.reshape(I, -1) for x in jax.tree.leaves(sums)], axis=1)
    b_i = jnp.minimum(data.counts, B).astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(jnp.square(flat / b_i[:, None]), axis=1))
    clip = float(jnp.median(norms))     # binds for about half the clients
    dp = privacy.DPConfig(clip_norm=clip, epsilon=8.0, delta=DELTA)
    _, _, up = fed.sample_round(psl, params, data, rk, B, dp=dp)
    expected = (np.asarray(norms) > clip).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(up["dp"]["clipped"]), expected)
    assert 0.0 < expected.mean() < 1.0  # the clip genuinely splits clients


def test_driver_epsilon_is_accountant_not_naive_composition():
    # 2 rounds at S-of-I participation: the streamed ε must equal the
    # hand-computed subsampled-RDP composition — and NOT 2× the
    # single-release ε (naive per-round composition)
    data = _data(jax.random.PRNGKey(0))
    fl = _fl()
    params0 = _params0(jax.random.PRNGKey(1))
    dp = privacy.DPConfig(clip_norm=5.0, epsilon=4.0, delta=DELTA)
    res = algorithms.algorithm1(psl, params0, data, fl, 2,
                                jax.random.PRNGKey(3), participation=S,
                                dp=dp)
    q, sigma = S / I, privacy.noise_multiplier(dp)

    def rdp_one(a):
        s = sum(math.comb(a, k) * (1 - q) ** (a - k) * q ** k
                * math.exp(k * (k - 1) / (2.0 * sigma ** 2))
                for k in range(a + 1))
        return math.log(s) / (a - 1)

    # hand computation over small orders only (comb/exp stay exact there);
    # the accountant's wider grid can only find a smaller min, so allow it
    hand = min(2.0 * rdp_one(a) + math.log(1.0 / DELTA) / (a - 1)
               for a in range(2, 33))
    got = float(np.asarray(res.history["round_dp_epsilon"])[-1])
    assert got == pytest.approx(hand, rel=1e-4)
    assert got < 2 * dp.epsilon        # tighter than naive ε-per-release × K


def test_deprecated_dp_sample_round_warns_and_delegates():
    data = _data(jax.random.PRNGKey(0))
    params = _params0(jax.random.PRNGKey(1))
    dp = privacy.DPConfig(clip_norm=5.0, epsilon=4.0, delta=DELTA)
    rk = jax.random.PRNGKey(7)
    with pytest.warns(DeprecationWarning,
                      match=r"\[FLT004\].*dp_sample_round"):
        g_old, q_old = privacy.dp_sample_round(psl, params, data, rk, B, dp)
    g_new, _, up = fed.sample_round(psl, params, data, rk, B, dp=dp)
    _assert_trees_close(g_old, g_new, rtol=1e-6, atol=1e-7)
    _assert_trees_close(q_old, up["q_grad_sums"], rtol=1e-6, atol=1e-7)


def test_cohort_efstore_dp_composition_runs():
    # cohort engine + EFStore + int8 + DP in one driver call (the full
    # stack); 3 rounds must produce finite params and a noised trajectory
    data = _data(jax.random.PRNGKey(0))
    fl = _fl()
    params0 = _params0(jax.random.PRNGKey(1))
    dp = privacy.DPConfig(clip_norm=5.0, epsilon=4.0, delta=DELTA)
    r = algorithms.algorithm1(psl, params0, data, fl, 3,
                              jax.random.PRNGKey(3), participation=S,
                              cohort=True, codec=make_codec("int8"), dp=dp)
    for x in jax.tree.leaves(r.params):
        assert np.isfinite(np.asarray(x)).all()
    r0 = algorithms.algorithm1(psl, params0, data, fl, 3,
                               jax.random.PRNGKey(3), participation=S,
                               cohort=True, codec=make_codec("int8"))
    # the noise must actually change the trajectory
    diffs = [float(jnp.max(jnp.abs(a - b))) for a, b in
             zip(jax.tree.leaves(r.params), jax.tree.leaves(r0.params))]
    assert max(diffs) > 1e-4


# ---------------------------------------------------------------------------
# checkpoint dtype gate (satellite fix)
# ---------------------------------------------------------------------------


def test_checkpoint_dtype_mismatch_raises(tmp_path):
    from repro.checkpoint import load_checkpoint, save_checkpoint
    path = str(tmp_path / "ck.msgpack")
    # build the f64 leaves in numpy — jnp would silently downcast them
    # before they ever hit the file (x64 is disabled in tests)
    save_checkpoint(path, {"w": np.ones((3,), np.float64)}, step=3)
    with pytest.raises(ValueError, match="dtype mismatch"):
        load_checkpoint(path, {"w": jnp.ones((3,), jnp.float32)})


def test_checkpoint_cast_true_converts(tmp_path):
    from repro.checkpoint import load_checkpoint, save_checkpoint
    path = str(tmp_path / "ck.msgpack")
    save_checkpoint(path, {"w": np.arange(3, dtype=np.float64) * 0.5}, step=3)
    tree, step = load_checkpoint(path, {"w": jnp.ones((3,), jnp.float32)},
                                 cast=True)
    assert step == 3
    assert tree["w"].dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(tree["w"]), [0.0, 0.5, 1.0])


def test_checkpoint_matching_dtypes_load_without_cast(tmp_path):
    from repro.checkpoint import load_checkpoint, save_checkpoint
    path = str(tmp_path / "ck.msgpack")
    tree0 = {"w": np.ones((2, 2), np.float32), "n": np.int32(4)}
    save_checkpoint(path, tree0, step=1)
    tree, _ = load_checkpoint(path, tree0)
    np.testing.assert_array_equal(np.asarray(tree["w"]), tree0["w"])

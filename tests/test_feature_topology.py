"""Feature-based (vertical FL) topology invariants (DESIGN.md §12): the
sharded feature engine — each client on its own "model"-axis shard, the
paper's Alg-3 step-4 h-exchange realized as a tiled `lax.all_gather` —
reproduces the local vmap reference at atol 1e-5 for Algorithms 3 AND 4,
dense and with the int8 + error-feedback composition, and the compressed
wire formats agree bit-for-bit across topologies (the all_gather reassembles
the full h in canonical client order on every shard, so h_sum, the head
gradient, and each client's block gradient see identical inputs).

One deliberate exception: Algorithm 4's ν comes from the Lemma-1 closed
form (sqrt/divides on surrogate aggregates up to penalty_c = 1e4), whose
float reassociation differs once collectives are in the graph — ν is
compared relatively (rtol 1e-3) while loss/slack trajectories hold the
absolute 1e-5/1e-4 pins.

On a single-device run (tier-1 CI) the mesh degenerates to one shard, which
still exercises the shard_map + all_gather code path; the multi-device CI
job (XLA_FLAGS=--xla_force_host_platform_device_count=8) runs the same
tests with real client distribution plus the 8-device-only case below.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommCarry, ef_init, ef_init_stacked, make_codec
from repro.comm.accounting import all_gather_axis_bytes
from repro.configs.base import FLConfig
from repro.core import algorithms, baselines, fed
from repro.core.topology import (LocalTopology, ShardedTopology,
                                 feature_sharded_for)
from repro.launch.mesh import make_feature_mesh
from repro.models import mlp

P, J, L = 16, 8, 3
I = 4                                  # feature clients; divisible by 1/2/4
B = 20
D_HEAD = L * J                         # flattened w0 stream
D_BLOCK = J * (P // I)                 # flattened per-client block stream


def _topo(num_clients: int = I) -> ShardedTopology:
    """Most devices that divide the client count (4 in the multi-device CI
    job, 1 in tier-1 — still the shard_map + all_gather path)."""
    return feature_sharded_for(num_clients)


def _data(key, n=400):
    z = jax.random.normal(key, (n, P))
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, L)
    return fed.partition_features(z, jax.nn.one_hot(lab, L), I)


def _params0(key):
    return {"w0": jax.random.normal(key, (L, J)) * 0.2,
            "blocks": jax.random.normal(jax.random.fold_in(key, 1),
                                        (I, J, P // I)) * 0.2}


def _fl(**kw):
    base = dict(batch_size=B, a1=0.9, a2=0.5, alpha_rho=0.1,
                alpha_gamma=0.6, tau=0.2)
    base.update(kw)
    return FLConfig(**base)


def _ef0():
    return {"w0": ef_init(D_HEAD), "blocks": ef_init_stacked(I, D_BLOCK)}


def _assert_trees_close(a, b, **kw):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


def _round(params, data, codec=None, ef=None, topology=None):
    return fed.feature_round(params, data, jax.random.PRNGKey(2), B,
                             mlp.per_sample_loss_from_h, mlp.client_h,
                             codec=codec, ef=ef, topology=topology)


# ---------------------------------------------------------------------------
# single-round equivalence (the engine itself)
# ---------------------------------------------------------------------------


def test_feature_round_sharded_matches_local_dense():
    data = _data(jax.random.PRNGKey(0))
    params = _params0(jax.random.PRNGKey(1))
    g_l, v_l, up_l = _round(params, data)
    g_s, v_s, up_s = _round(params, data, topology=_topo())
    # the all_gather reassembles the identical h every shard saw locally
    np.testing.assert_array_equal(np.asarray(up_l["h_exchange"]),
                                  np.asarray(up_s["h_exchange"]))
    _assert_trees_close(g_l, g_s, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(v_l), float(v_s), rtol=1e-6)
    assert up_s["h_exchange"].shape == (I, B, J)


def test_feature_round_sharded_int8_wire_format_matches_local_exactly():
    """Head/block codec keys are derived identically for every topology and
    each shard quantizes the same gradients, so the encoded wire values
    (int8 levels + scales) agree bit-for-bit — the compression boundary
    does not move when the clients do."""
    data = _data(jax.random.PRNGKey(3))
    params = _params0(jax.random.PRNGKey(1))
    codec = make_codec("int8")
    _, _, up_l = _round(params, data, codec=codec)
    _, _, up_s = _round(params, data, codec=codec, topology=_topo())
    for stream in ("q_head", "q_blocks"):
        np.testing.assert_array_equal(
            np.asarray(up_l["encoded"][stream].values),
            np.asarray(up_s["encoded"][stream].values))
        np.testing.assert_allclose(
            np.asarray(up_l["encoded"][stream].scales),
            np.asarray(up_s["encoded"][stream].scales), rtol=1e-6)
    for stream in ("w0", "blocks"):
        np.testing.assert_allclose(np.asarray(up_l["ef"][stream]),
                                   np.asarray(up_s["ef"][stream]), atol=1e-6)


def test_feature_round_validation_parity_with_sample_round():
    """Both round functions reject malformed codec/EF arguments with the
    same message shapes (the shared _check_* helpers)."""
    data = _data(jax.random.PRNGKey(0))
    params = _params0(jax.random.PRNGKey(1))
    z = jax.random.normal(jax.random.PRNGKey(4), (400, P))
    y = jax.nn.one_hot(jnp.zeros(400, jnp.int32), L)
    sdata = fed.partition_samples(z, y, I)
    sparams = mlp.init(jax.random.PRNGKey(1), P, J, L)

    # EF residuals without a codec are rejected, not silently dropped
    with pytest.raises(ValueError, match="feature_round: .*without codec="):
        _round(params, data, ef=_ef0())
    with pytest.raises(ValueError, match="sample_round: .*without codec="):
        fed.sample_round(mlp.per_sample_loss, sparams, sdata,
                         jax.random.PRNGKey(2), B, ef=jnp.zeros((I, 4)))

    codec = make_codec("int8")
    # feature EF must be the two-stream dict
    with pytest.raises(ValueError, match="'w0' and 'blocks'"):
        _round(params, data, codec=codec, ef=ef_init(D_HEAD))
    with pytest.raises(ValueError, match="'w0' and 'blocks'"):
        _round(params, data, codec=codec, ef={"w0": ef_init(D_HEAD)})

    # per-stream shape mismatches name the stream and the expected shape
    bad = _ef0()
    bad["blocks"] = ef_init_stacked(I + 1, D_BLOCK)
    with pytest.raises(ValueError,
                       match=r"stream 'blocks' have shape .* expected"):
        _round(params, data, codec=codec, ef=bad)
    bad = _ef0()
    bad["w0"] = ef_init(D_HEAD + 1)
    with pytest.raises(ValueError,
                       match=r"stream 'w0' have shape .* expected"):
        _round(params, data, codec=codec, ef=bad)
    with pytest.raises(ValueError,
                       match=r"stream 'q_grad' have shape .* expected"):
        fed.sample_round(mlp.per_sample_loss, sparams, sdata,
                         jax.random.PRNGKey(2), B, codec=codec,
                         ef=jnp.zeros((I + 1, 4)))


# ---------------------------------------------------------------------------
# trajectory equality: Algorithms 3 and 4, dense and fully composed
# ---------------------------------------------------------------------------


def test_algorithm3_sharded_matches_local_trajectory():
    data = _data(jax.random.PRNGKey(0))
    params0 = _params0(jax.random.PRNGKey(1))
    fl = _fl()
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0)
    r_l = algorithms.algorithm3(mlp.per_sample_loss_from_h, mlp.client_h,
                                params0, data, fl, 50, **kw)
    r_s = algorithms.algorithm3(mlp.per_sample_loss_from_h, mlp.client_h,
                                params0, data, fl, 50, topology=_topo(), **kw)
    np.testing.assert_allclose(np.asarray(r_s.history["round_loss_est"]),
                               np.asarray(r_l.history["round_loss_est"]),
                               atol=1e-5)
    _assert_trees_close(r_s.params, r_l.params, atol=1e-5)


def test_algorithm3_sharded_matches_local_int8_ef():
    """The codec + error-feedback composition through the all_gather — the
    refactor's risk surface for the vertical stack."""
    data = _data(jax.random.PRNGKey(3))
    params0 = _params0(jax.random.PRNGKey(1))
    fl = _fl()
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0,
              codec=make_codec("int8"))
    r_l = algorithms.algorithm3(mlp.per_sample_loss_from_h, mlp.client_h,
                                params0, data, fl, 40, **kw)
    r_s = algorithms.algorithm3(mlp.per_sample_loss_from_h, mlp.client_h,
                                params0, data, fl, 40, topology=_topo(), **kw)
    np.testing.assert_allclose(np.asarray(r_s.history["round_loss_est"]),
                               np.asarray(r_l.history["round_loss_est"]),
                               atol=1e-5)
    # params tolerate one int8 quant-level flip (see test_topology.py)
    _assert_trees_close(r_s.params, r_l.params, atol=1e-4)


def test_algorithm4_sharded_matches_local_trajectory():
    data = _data(jax.random.PRNGKey(4))
    params0 = _params0(jax.random.PRNGKey(1))
    fl = _fl(constrained=True, cost_limit=1.0, penalty_c=1e4)
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0)
    r_l = algorithms.algorithm4(mlp.per_sample_loss_from_h, mlp.client_h,
                                params0, data, fl, 40, **kw)
    r_s = algorithms.algorithm4(mlp.per_sample_loss_from_h, mlp.client_h,
                                params0, data, fl, 40, topology=_topo(), **kw)
    np.testing.assert_allclose(np.asarray(r_s.history["round_loss_est"]),
                               np.asarray(r_l.history["round_loss_est"]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(r_s.history["round_slack"]),
                               np.asarray(r_l.history["round_slack"]),
                               atol=1e-4)
    # Lemma-1 ν reassociates under collectives; its scale reaches penalty_c
    np.testing.assert_allclose(np.asarray(r_s.history["round_nu"]),
                               np.asarray(r_l.history["round_nu"]),
                               rtol=1e-3, atol=1e-2)


def test_algorithm4_sharded_matches_local_int8_ef():
    """Algorithm 4 with the full int8 + EF composition (the acceptance
    criterion's 'including int8+EF' clause)."""
    data = _data(jax.random.PRNGKey(5))
    params0 = _params0(jax.random.PRNGKey(1))
    fl = _fl(constrained=True, cost_limit=1.0, penalty_c=1e4)
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0,
              codec=make_codec("int8"))
    r_l = algorithms.algorithm4(mlp.per_sample_loss_from_h, mlp.client_h,
                                params0, data, fl, 40, **kw)
    r_s = algorithms.algorithm4(mlp.per_sample_loss_from_h, mlp.client_h,
                                params0, data, fl, 40, topology=_topo(), **kw)
    np.testing.assert_allclose(np.asarray(r_s.history["round_loss_est"]),
                               np.asarray(r_l.history["round_loss_est"]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(r_s.history["round_nu"]),
                               np.asarray(r_l.history["round_nu"]),
                               rtol=1e-3, atol=1e-2)
    _assert_trees_close(r_s.params, r_l.params, atol=1e-4)


def test_algorithm3_sharded_matches_local_topk_ef():
    """The biased top-k codec that EF must repair, across topologies."""
    data = _data(jax.random.PRNGKey(6))
    params0 = _params0(jax.random.PRNGKey(1))
    fl = _fl()
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0,
              codec=make_codec("topk", topk_frac=0.3))
    r_l = algorithms.algorithm3(mlp.per_sample_loss_from_h, mlp.client_h,
                                params0, data, fl, 30, **kw)
    r_s = algorithms.algorithm3(mlp.per_sample_loss_from_h, mlp.client_h,
                                params0, data, fl, 30, topology=_topo(), **kw)
    np.testing.assert_allclose(np.asarray(r_s.history["round_loss_est"]),
                               np.asarray(r_l.history["round_loss_est"]),
                               atol=1e-5)
    _assert_trees_close(r_s.params, r_l.params, atol=1e-5)


# ---------------------------------------------------------------------------
# scan driver == per-round Python loop (run_feature_rounds)
# ---------------------------------------------------------------------------


def test_feature_scan_driver_matches_loop():
    data = _data(jax.random.PRNGKey(0))
    params0 = _params0(jax.random.PRNGKey(1))
    fl = _fl()
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0,
              codec=make_codec("int8"), topology=_topo())
    r_scan = algorithms.algorithm3(mlp.per_sample_loss_from_h, mlp.client_h,
                                   params0, data, fl, 30, **kw)
    r_loop = algorithms.algorithm3(mlp.per_sample_loss_from_h, mlp.client_h,
                                   params0, data, fl, 30, driver="loop", **kw)
    np.testing.assert_allclose(np.asarray(r_scan.history["round_loss_est"]),
                               np.asarray(r_loop.history["round_loss_est"]),
                               atol=1e-5)
    _assert_trees_close(r_scan.params, r_loop.params, atol=1e-4)


def test_feature_scan_driver_matches_loop_constrained():
    data = _data(jax.random.PRNGKey(4))
    params0 = _params0(jax.random.PRNGKey(1))
    fl = _fl(constrained=True, cost_limit=1.0, penalty_c=1e4)
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0)
    r_scan = algorithms.algorithm4(mlp.per_sample_loss_from_h, mlp.client_h,
                                   params0, data, fl, 30, **kw)
    r_loop = algorithms.algorithm4(mlp.per_sample_loss_from_h, mlp.client_h,
                                   params0, data, fl, 30, driver="loop", **kw)
    for k in ("round_loss_est", "round_slack"):
        np.testing.assert_allclose(np.asarray(r_scan.history[k]),
                                   np.asarray(r_loop.history[k]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r_scan.history["round_nu"]),
                               np.asarray(r_loop.history["round_nu"]),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# accounting + state placement
# ---------------------------------------------------------------------------


def test_feature_axis_bytes_metric_zero_local_positive_sharded():
    data = _data(jax.random.PRNGKey(0))
    params0 = _params0(jax.random.PRNGKey(1))
    fl = _fl()
    topo = _topo()
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0)
    r_l = algorithms.algorithm3(mlp.per_sample_loss_from_h, mlp.client_h,
                                params0, data, fl, 5, **kw)
    r_s = algorithms.algorithm3(mlp.per_sample_loss_from_h, mlp.client_h,
                                params0, data, fl, 5, topology=topo, **kw)
    assert float(r_l.history["round_axis_bytes"][0]) == 0.0
    expect = all_gather_axis_bytes(I * B * J, topo.num_shards)
    assert float(r_s.history["round_axis_bytes"][0]) == float(expect)
    if topo.num_shards > 1:
        assert expect > 0


def test_all_gather_axis_bytes_closed_form():
    assert all_gather_axis_bytes(100, 1) == 0
    assert all_gather_axis_bytes(100, 4) == 3 * 4 * 100
    assert all_gather_axis_bytes(100, 8) == 7 * 4 * 100


def test_place_feature_state_shards_block_residuals():
    topo = _topo()
    state = CommCarry(opt=None, ef=_ef0())
    placed = topo.place_feature_state(state)
    assert placed.ef["blocks"].shape == (I, D_BLOCK)
    assert len(placed.ef["blocks"].sharding.device_set) == topo.num_shards
    # the single head stream is replicated, not sharded
    assert placed.ef["w0"].shape == (D_HEAD,)
    # non-CommCarry states pass through untouched
    assert topo.place_feature_state("opaque") == "opaque"
    assert LocalTopology().place_feature_state(state) is state


def test_feature_ef_carry_survives_scan_sharded():
    data = _data(jax.random.PRNGKey(3))
    params0 = _params0(jax.random.PRNGKey(1))
    topo = _topo()
    r = algorithms.algorithm3(mlp.per_sample_loss_from_h, mlp.client_h,
                              params0, data, _fl(), 10,
                              key=jax.random.PRNGKey(2), eval_every=0,
                              codec=make_codec("int8"), topology=topo)
    ef = r.final_state.ef
    assert set(ef) == {"w0", "blocks"}
    assert ef["blocks"].shape == (I, D_BLOCK)
    assert len(ef["blocks"].sharding.device_set) == topo.num_shards


# ---------------------------------------------------------------------------
# constrained baselines ride the same engine
# ---------------------------------------------------------------------------


def test_feature_baselines_sharded_match_local():
    data = _data(jax.random.PRNGKey(7))
    params0 = _params0(jax.random.PRNGKey(1))
    fl = _fl(constrained=True, cost_limit=1.0, penalty_c=1e4)
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0)
    for run in (
            lambda topo: baselines.feature_frank_wolfe(
                mlp.per_sample_loss_from_h, mlp.client_h, params0, data, fl,
                baselines.FWConfig(), 15, topology=topo, **kw),
            lambda topo: baselines.feature_dual_decomposition(
                mlp.per_sample_loss_from_h, mlp.client_h, params0, data, fl,
                baselines.DualConfig(), 15, topology=topo, **kw)):
        r_l, r_s = run(None), run(_topo())
        loss = np.asarray(r_l.history["round_loss_est"])
        assert np.isfinite(loss).all()
        np.testing.assert_allclose(np.asarray(r_s.history["round_loss_est"]),
                                   loss, atol=1e-5)
        _assert_trees_close(r_s.params, r_l.params, atol=1e-5)


# ---------------------------------------------------------------------------
# multi-device-only coverage (the dedicated CI job)
# ---------------------------------------------------------------------------


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8 (multi-device CI job)")
def test_eight_device_eight_feature_clients_full_composition():
    """One feature client per device on the full 8-device mesh, Algorithm 4
    with int8 + EF — the acceptance-criterion configuration at real
    distribution."""
    I8 = 8
    z = jax.random.normal(jax.random.PRNGKey(9), (640, I8 * 4))
    lab = jax.random.randint(jax.random.PRNGKey(10), (640,), 0, L)
    data = fed.partition_features(z, jax.nn.one_hot(lab, L), I8)
    params0 = {"w0": jax.random.normal(jax.random.PRNGKey(1), (L, J)) * 0.2,
               "blocks": jax.random.normal(jax.random.PRNGKey(11),
                                           (I8, J, 4)) * 0.2}
    topo = ShardedTopology(make_feature_mesh(8), axes=("model",))
    assert topo.num_shards == 8
    fl = _fl(constrained=True, cost_limit=1.0, penalty_c=1e4)
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0,
              codec=make_codec("int8"))
    r_l = algorithms.algorithm4(mlp.per_sample_loss_from_h, mlp.client_h,
                                params0, data, fl, 30, **kw)
    r_s = algorithms.algorithm4(mlp.per_sample_loss_from_h, mlp.client_h,
                                params0, data, fl, 30, topology=topo, **kw)
    np.testing.assert_allclose(np.asarray(r_s.history["round_loss_est"]),
                               np.asarray(r_l.history["round_loss_est"]),
                               atol=1e-5)
    _assert_trees_close(r_s.params, r_l.params, atol=1e-4)

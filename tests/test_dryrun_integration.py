"""Integration test of the multi-pod dry-run path itself (deliverable (e)).

Runs launch/dryrun.py in a subprocess (it needs 512 virtual devices, which
must never leak into this test process) for the cheapest arch on both meshes
and checks lower+compile succeeded and the roofline fields are populated.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("flags", [[], ["--multi-pod"]])
def test_dryrun_mnist_mlp_both_meshes(tmp_path, flags):
    out = str(tmp_path / "r.json")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mnist-mlp",
         "--shape", "train_4k", "--json", out] + flags,
        env=env, cwd=REPO, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    r = json.load(open(out))[0]
    assert r["status"] == "ok"
    assert r["chips"] == (512 if flags else 256)
    assert r["flops"] > 0 and r["bytes"] > 0
    assert r["bottleneck"] in ("compute", "memory", "collective")


def test_dryrun_config_override(tmp_path):
    out = str(tmp_path / "r.json")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mnist-mlp",
         "--shape", "train_4k", "--set", "dtype=float32", "--json", out],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert json.load(open(out))[0]["status"] == "ok"

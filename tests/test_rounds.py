"""Scan-round driver + heterogeneous-protocol invariants: scan==loop
trajectories, unbiased partial-participation aggregation, exactly-once
Dirichlet partitioning, and ragged-masked == dense batch selection."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import algorithms, fed, optimizer, rounds
from repro.models import mlp

P, J, L = 12, 6, 3


def _data(key, n=240):
    z = jax.random.normal(key, (n, P))
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, L)
    return z, jax.nn.one_hot(lab, L)


def psl(p, z, y):
    return mlp.per_sample_loss(p, z, y)


def _fl(**kw):
    base = dict(batch_size=20, a1=0.9, a2=0.5, alpha_rho=0.1,
                alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5)
    base.update(kw)
    return FLConfig(**base)


# ---------------------------------------------------------------------------
# scan driver == per-round Python loop
# ---------------------------------------------------------------------------


def test_scan_driver_matches_loop_algorithm1():
    z, y = _data(jax.random.PRNGKey(0))
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, 4)
    fl = _fl()
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0)
    r_scan = algorithms.algorithm1(psl, params0, data, fl, 60, **kw)
    r_loop = algorithms.algorithm1(psl, params0, data, fl, 60, driver="loop",
                                   **kw)
    np.testing.assert_allclose(np.asarray(r_scan.history["round_loss_est"]),
                               np.asarray(r_loop.history["round_loss_est"]),
                               atol=1e-5)
    for a, b in zip(jax.tree.leaves(r_scan.params),
                    jax.tree.leaves(r_loop.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_scan_driver_matches_loop_constrained_and_participation():
    z, y = _data(jax.random.PRNGKey(3))
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_dirichlet(z, y, 5, jax.random.PRNGKey(4), alpha=0.4)
    fl = _fl(constrained=True, cost_limit=1.2, penalty_c=1e4)
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0, participation=2)
    r_scan = algorithms.algorithm2(psl, params0, data, fl, 40, **kw)
    r_loop = algorithms.algorithm2(psl, params0, data, fl, 40, driver="loop",
                                   **kw)
    for k in ("round_loss_est", "round_slack"):
        np.testing.assert_allclose(np.asarray(r_scan.history[k]),
                                   np.asarray(r_loop.history[k]), atol=1e-5)
    # nu's scale is set by penalty_c (up to 1e4), so compare relatively
    np.testing.assert_allclose(np.asarray(r_scan.history["round_nu"]),
                               np.asarray(r_loop.history["round_nu"]),
                               rtol=1e-4, atol=1e-4)


def test_explicit_schedule_inputs_match_state_derived():
    """Threading precomputed rho/gamma through the scan must equal letting
    ssca_step derive them from the carried t (incl. the rho(1)=1 rule)."""
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    fl = _fl()
    grad = jax.tree.map(jnp.ones_like, params0)
    s_implicit = s_explicit = optimizer.ssca_init(params0)
    rho, gamma = rounds.schedule_arrays(fl, 1, 5)
    for i in range(5):
        s_implicit = optimizer.ssca_step(s_implicit, grad, fl)
        s_explicit = optimizer.ssca_step(s_explicit, grad, fl,
                                         rho_t=rho[i], gamma_t=gamma[i])
    for a, b in zip(jax.tree.leaves(s_implicit.params),
                    jax.tree.leaves(s_explicit.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_rounds_eval_chunking_histories():
    z, y = _data(jax.random.PRNGKey(0))
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, 4)
    fl = _fl()

    def eval_fn(params, state):
        return {"loss": float(mlp.mean_loss(params, z, y))}

    r = algorithms.algorithm1(psl, params0, data, fl, 40,
                              jax.random.PRNGKey(2), eval_fn=eval_fn,
                              eval_every=10)
    assert r.history["round"].shape == (4,)
    assert r.history["loss"].shape == (4,)
    # full per-round series ride along
    assert r.history["round_loss_est"].shape == (40,)
    np.testing.assert_array_equal(np.asarray(r.history["round_t"]),
                                  np.arange(1, 41))


# ---------------------------------------------------------------------------
# partial participation
# ---------------------------------------------------------------------------


def test_participation_mask_uniform_without_replacement():
    I, S = 5, 2
    masks = jax.vmap(lambda k: fed.participation_mask(k, I, S))(
        jax.random.split(jax.random.PRNGKey(0), 4000))
    np.testing.assert_array_equal(np.asarray(jnp.sum(masks, axis=1)),
                                  np.full(4000, S))            # exactly S
    freq = np.asarray(jnp.mean(masks, axis=0))
    np.testing.assert_allclose(freq, S / I, atol=0.03)         # uniform


def test_participation_weights_unbiased():
    """E over the participation draw of the reweighted aggregation weights
    equals the full-participation weights (Horvitz-Thompson)."""
    counts = jnp.array([70, 30, 50, 10], jnp.int32)
    B = 5
    dense_w = fed.aggregation_weights(counts, B)
    masks = jax.vmap(lambda k: fed.participation_mask(k, 4, 2))(
        jax.random.split(jax.random.PRNGKey(1), 20000))
    ws = jax.vmap(lambda m: fed.aggregation_weights(counts, B, m))(masks)
    np.testing.assert_allclose(np.asarray(jnp.mean(ws, axis=0)),
                               np.asarray(dense_w), rtol=0.05)


def test_participation_grad_estimate_unbiased():
    """Averaging sample_round's grad estimate over participation draws (same
    batch key) converges to the full-participation estimate."""
    z, y = _data(jax.random.PRNGKey(0), n=120)
    params = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, 4)
    key, B = jax.random.PRNGKey(2), 10
    dense, _, _ = fed.sample_round(psl, params, data, key, B)

    def one(pk):
        g, _, _ = fed.sample_round(psl, params, data, key, B,
                                   participation=2, participation_key=pk)
        return g

    gs = jax.vmap(one)(jax.random.split(jax.random.PRNGKey(3), 600))
    mean_g = jax.tree.map(lambda u: jnp.mean(u, axis=0), gs)
    for a, b in zip(jax.tree.leaves(mean_g), jax.tree.leaves(dense)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0.15, atol=5e-3)


def test_participation_equal_to_num_clients_is_dense():
    z, y = _data(jax.random.PRNGKey(0), n=120)
    params = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, 4)
    dense, _, _ = fed.sample_round(psl, params, data, jax.random.PRNGKey(2), 10)
    same, _, up = fed.sample_round(psl, params, data, jax.random.PRNGKey(2),
                                   10, participation=4)
    assert up["participants"] is None
    for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(same)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Dirichlet non-IID partitioning
# ---------------------------------------------------------------------------


def test_dirichlet_partition_preserves_every_sample_exactly_once():
    n = 500
    z = jnp.arange(n, dtype=jnp.float32)[:, None] * jnp.ones((1, P))
    lab = jax.random.randint(jax.random.PRNGKey(0), (n,), 0, L)
    y = jax.nn.one_hot(lab, L)
    data = fed.partition_dirichlet(z, y, 7, jax.random.PRNGKey(1), alpha=0.3)
    assert int(data.total) == n
    seen = []
    for i in range(7):
        c = int(data.counts[i])
        assert c >= 1
        seen.extend(np.asarray(data.features[i, :c, 0]).astype(int).tolist())
        # padding rows are zero
        assert float(jnp.abs(data.features[i, c:]).sum()) == 0.0
    assert sorted(seen) == list(range(n))


def test_dirichlet_alpha_controls_label_skew():
    z, y = _data(jax.random.PRNGKey(5), n=3000)

    def mean_label_entropy(alpha):
        data = fed.partition_dirichlet(z, y, 10, jax.random.PRNGKey(6),
                                       alpha=alpha)
        ents = []
        for i in range(10):
            c = int(data.counts[i])
            p = np.asarray(jnp.sum(data.labels[i, :c], axis=0)) / c
            ents.append(-(p[p > 0] * np.log(p[p > 0])).sum())
        return np.mean(ents)

    assert mean_label_entropy(0.05) < mean_label_entropy(50.0) - 0.3


# ---------------------------------------------------------------------------
# ragged masked batches
# ---------------------------------------------------------------------------


def test_ragged_masked_matches_dense_when_equal_counts():
    z, y = _data(jax.random.PRNGKey(0), n=240)
    params = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, 4)          # all N_i = 60 >= B
    g, v, _ = fed.sample_round(psl, params, data, jax.random.PRNGKey(2), 20)
    # dense reference: unmasked manual aggregation with N_i/(BN)
    idx = fed.sample_batches(data, jax.random.PRNGKey(2), 20)
    zs = jnp.concatenate([data.features[i][idx[i]] for i in range(4)])
    ys = jnp.concatenate([data.labels[i][idx[i]] for i in range(4)])
    ref = jax.grad(lambda p: jnp.mean(psl(p, zs, ys)))(params)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=1e-6)


def test_small_client_batches_are_masked_and_reweighted():
    """A client with N_i < B contributes a B_i = N_i masked batch with weight
    N_i/(B_i·N) — padding rows never leak into the estimate."""
    key = jax.random.PRNGKey(7)
    z, y = _data(key, n=64)
    params = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_ragged([z[:60], z[60:]], [y[:60], y[60:]])
    assert [int(c) for c in data.counts] == [60, 4]
    B = 16
    g, v, _ = fed.sample_round(psl, params, data, key, B, with_value=True)
    idx = fed.sample_batches(data, key, B)
    mask = fed.batch_mask(data.counts, B)
    w = [60 / (16 * 64), 4 / (4 * 64)]             # N_i/(min(B,N_i)·N)

    def q(i):
        zb = data.features[i][idx[i]]
        yb = data.labels[i][idx[i]]
        return jax.grad(lambda p: jnp.sum(psl(p, zb, yb) * mask[i]))(params)

    ref = jax.tree.map(lambda a, b: w[0] * a + w[1] * b, q(0), q(1))
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=1e-6)


def test_heterogeneous_run_converges():
    """End-to-end: Dirichlet non-IID + partial participation still decreases
    the training cost under the scan driver (Theorem 1 regime)."""
    z, y = _data(jax.random.PRNGKey(8), n=600)
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_dirichlet(z, y, 6, jax.random.PRNGKey(9), alpha=0.3)
    fl = _fl(batch_size=30)

    def eval_fn(params, state):
        return {"loss": float(mlp.mean_loss(params, z, y))}

    r = algorithms.algorithm1(psl, params0, data, fl, 120,
                              jax.random.PRNGKey(2), eval_fn=eval_fn,
                              eval_every=40, participation=3)
    losses = np.asarray(r.history["loss"])
    assert np.isfinite(losses).all()
    assert losses[-1] < float(mlp.mean_loss(params0, z, y))

"""Hypothesis property tests for the compression codecs (skipped without the
``dev`` extra, like the other property suites): stochastic-rounding
quantizers are unbiased for arbitrary inputs, error feedback conserves mass
for every codec, and top-k with frac=1 is lossless at any length."""
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.comm import codecs, error_feedback


@settings(deadline=None, max_examples=15)
@given(st.integers(0, 2**31 - 1), st.integers(1, 300),
       st.sampled_from([4, 8]), st.floats(1e-3, 1e3))
def test_quantizer_unbiased(seed, p, bits, scale):
    """E[decode(encode(x))] == x within a CLT band, for any length, bit
    width, and input magnitude (per-chunk absmax scaling never clips)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (p,)) * scale
    sq = codecs.StochasticQuantizer(bits=bits, chunk=64)
    m = 1500
    keys = jax.random.split(jax.random.fold_in(key, 1), m)
    xh = jax.vmap(lambda k: sq.roundtrip(x, k)[1])(keys)
    bias = np.abs(np.asarray(jnp.mean(xh, axis=0) - x))
    max_scale = float(jnp.max(sq.encode(x, keys[0]).scales))
    assert bias.max() < max(6 * max_scale * 0.5 / np.sqrt(m), 1e-7)


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 2**31 - 1), st.integers(2, 200),
       st.floats(0.01, 1.0))
def test_ef_conservation_any_codec(seed, p, frac):
    """x_hat + r' == x + r: error feedback never loses mass, so whatever
    top-k drops this round is re-offered next round (k -> P consistency)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (p,))
    r = jax.random.normal(jax.random.fold_in(key, 1), (p,))
    for codec in (codecs.TopK(frac=frac),
                  codecs.StochasticQuantizer(bits=8, chunk=32)):
        _, xhat, r2 = error_feedback.ef_roundtrip(
            codec, x, r, jax.random.fold_in(key, 2))
        np.testing.assert_allclose(np.asarray(xhat + r2), np.asarray(x + r),
                                   atol=1e-5)


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 2**31 - 1), st.integers(1, 500))
def test_topk_full_fraction_lossless(seed, p):
    x = jax.random.normal(jax.random.PRNGKey(seed), (p,))
    _, xhat = codecs.TopK(frac=1.0).roundtrip(x)
    np.testing.assert_array_equal(np.asarray(xhat), np.asarray(x))

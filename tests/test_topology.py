"""Topology-layer invariants (DESIGN.md §11): the sharded client-execution
engine (shard_map over the mesh client axes, eq.-(9) aggregation as a
weighted psum, codec/EF applied per shard before the collective) reproduces
the local vmap reference trajectory at atol 1e-5 — including with the three
risk-surface subsystems (codec=int8 + error feedback + partial
participation) enabled at once, and on ragged Dirichlet partitions.

On a single-device run (tier-1 CI) the mesh degenerates to one shard, which
still exercises the shard_map + psum code path; the multi-device CI job
(XLA_FLAGS=--xla_force_host_platform_device_count=8) runs the same tests
with real client distribution plus the 8-device-only cases below.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommCarry, ef_init_stacked, make_codec
from repro.comm.accounting import psum_axis_bytes
from repro.configs.base import FLConfig
from repro.core import algorithms, baselines, fed
from repro.core.local_updates import algorithm1_local
from repro.core.topology import (LOCAL, LocalTopology, ShardedTopology,
                                 make_topology, sharded_for)
from repro.launch.mesh import make_client_mesh
from repro.models import mlp

P, J, L = 12, 6, 3
I = 8                                  # client count; divisible by 1/2/4/8


def _shard_topo(num_clients: int = I) -> ShardedTopology:
    """Sharded topology over the most devices that divide the client count
    (all 8 in the multi-device CI job, 1 in tier-1 — still the psum path)."""
    return sharded_for(num_clients)


def _data(key, n=240):
    z = jax.random.normal(key, (n, P))
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, L)
    return z, jax.nn.one_hot(lab, L)


def psl(p, z, y):
    return mlp.per_sample_loss(p, z, y)


def _fl(**kw):
    base = dict(batch_size=20, a1=0.9, a2=0.5, alpha_rho=0.1,
                alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5)
    base.update(kw)
    return FLConfig(**base)


def _assert_trees_close(a, b, **kw):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


# ---------------------------------------------------------------------------
# single-round equivalence (the engine itself)
# ---------------------------------------------------------------------------


def test_sample_round_sharded_matches_local_dense():
    z, y = _data(jax.random.PRNGKey(0))
    params = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, I)
    g_l, v_l, up_l = fed.sample_round(psl, params, data, jax.random.PRNGKey(2),
                                      20)
    g_s, v_s, up_s = fed.sample_round(psl, params, data, jax.random.PRNGKey(2),
                                      20, topology=_shard_topo())
    _assert_trees_close(g_l, g_s, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(v_l), float(v_s), rtol=1e-5)
    # the privacy surface is topology-invariant: per-client uploads keep
    # their global (I, ...) shapes and per-client values ride along
    for a, b in zip(jax.tree.leaves(up_l["q_grad_sums"]),
                    jax.tree.leaves(up_s["q_grad_sums"])):
        assert a.shape == b.shape
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-6)


def test_sample_round_sharded_int8_wire_format_matches_local_exactly():
    """Per-client codec keys are computed identically for every topology, so
    the encoded wire values (int8 levels + scales) agree bit-for-bit —
    the compression boundary does not move when the clients do."""
    z, y = _data(jax.random.PRNGKey(3))
    params = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, I)
    codec = make_codec("int8")
    _, _, up_l = fed.sample_round(psl, params, data, jax.random.PRNGKey(2),
                                  20, codec=codec)
    _, _, up_s = fed.sample_round(psl, params, data, jax.random.PRNGKey(2),
                                  20, codec=codec, topology=_shard_topo())
    np.testing.assert_array_equal(np.asarray(up_l["encoded"].values),
                                  np.asarray(up_s["encoded"].values))
    np.testing.assert_allclose(np.asarray(up_l["encoded"].scales),
                               np.asarray(up_s["encoded"].scales),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(up_l["ef"]), np.asarray(up_s["ef"]),
                               atol=1e-6)


def test_sharded_requires_divisible_clients():
    topo = _shard_topo()
    if topo.num_shards < 2:
        pytest.skip("needs a >= 2-device mesh to make divisibility fail")
    z, y = _data(jax.random.PRNGKey(0), n=210)
    params = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, topo.num_shards + 1)
    with pytest.raises(ValueError, match="divisible"):
        fed.sample_round(psl, params, data, jax.random.PRNGKey(2), 20,
                         topology=topo)


def test_make_topology_names():
    assert make_topology("local") is LOCAL
    topo = make_topology("sharded", mesh=make_client_mesh(1))
    assert topo.name == "sharded" and topo.num_shards == 1
    with pytest.raises(ValueError, match="unknown topology"):
        make_topology("ring")


# ---------------------------------------------------------------------------
# trajectory equality: Algorithms 1 and 2, dense and fully composed
# ---------------------------------------------------------------------------


def test_algorithm1_sharded_matches_local_trajectory():
    z, y = _data(jax.random.PRNGKey(0))
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, I)
    fl = _fl()
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0)
    r_l = algorithms.algorithm1(psl, params0, data, fl, 60, **kw)
    r_s = algorithms.algorithm1(psl, params0, data, fl, 60,
                                topology=_shard_topo(), **kw)
    np.testing.assert_allclose(np.asarray(r_s.history["round_loss_est"]),
                               np.asarray(r_l.history["round_loss_est"]),
                               atol=1e-5)
    _assert_trees_close(r_s.params, r_l.params, atol=1e-5)


def test_algorithm1_sharded_matches_local_int8_ef_participation():
    """The three-subsystem composition (codec + error feedback + partial
    participation) through the collective — the refactor's risk surface."""
    z, y = _data(jax.random.PRNGKey(3))
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, I)
    fl = _fl()
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0, participation=3,
              codec=make_codec("int8"))
    r_l = algorithms.algorithm1(psl, params0, data, fl, 40, **kw)
    r_s = algorithms.algorithm1(psl, params0, data, fl, 40,
                                topology=_shard_topo(), **kw)
    np.testing.assert_allclose(np.asarray(r_s.history["round_loss_est"]),
                               np.asarray(r_l.history["round_loss_est"]),
                               atol=1e-5)
    # params tolerate one int8 quant-level flip: a ~1e-7 reassociation
    # difference near a stochastic-rounding boundary flips one level (one
    # scale step ~1e-3 on one q coordinate), which EF re-injects next round —
    # the trajectory stays 1e-5-aligned while a recent flip can leave ~1e-4
    # on a single weight. (Residuals themselves differ by whole quant steps
    # at flipped coordinates by construction, so they are not compared.)
    _assert_trees_close(r_s.params, r_l.params, atol=1e-4)
    # the EF carry survives the scan round-trip shard-resident
    ef_s = r_s.final_state.ef
    assert ef_s.shape[0] == I
    assert len(ef_s.sharding.device_set) == _shard_topo().num_shards


def test_algorithm2_sharded_matches_local_int8_ef_participation():
    z, y = _data(jax.random.PRNGKey(4))
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_dirichlet(z, y, I, jax.random.PRNGKey(5), alpha=0.5)
    fl = _fl(constrained=True, cost_limit=1.2, penalty_c=1e4)
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0, participation=3,
              codec=make_codec("int8"))
    r_l = algorithms.algorithm2(psl, params0, data, fl, 40, **kw)
    r_s = algorithms.algorithm2(psl, params0, data, fl, 40,
                                topology=_shard_topo(), **kw)
    for k in ("round_loss_est", "round_slack"):
        np.testing.assert_allclose(np.asarray(r_s.history[k]),
                                   np.asarray(r_l.history[k]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r_s.history["round_nu"]),
                               np.asarray(r_l.history["round_nu"]),
                               rtol=1e-4, atol=1e-4)


def test_algorithm2_general_sharded_matches_local_topk_ef():
    """Dict-valued EF carry ({obj, cons} residual matrices) through the
    sharded scan, with the biased top-k codec that EF must repair."""
    z, y = _data(jax.random.PRNGKey(6))
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, I)
    fl = _fl(constrained=True, cost_limit=1.2, penalty_c=1e4)
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0,
              codec=make_codec("topk", topk_frac=0.3))
    r_l = algorithms.algorithm2_general(psl, psl, params0, data, fl, 30, **kw)
    r_s = algorithms.algorithm2_general(psl, psl, params0, data, fl, 30,
                                        topology=_shard_topo(), **kw)
    np.testing.assert_allclose(np.asarray(r_s.history["round_cons_est"]),
                               np.asarray(r_l.history["round_cons_est"]),
                               atol=1e-5)
    _assert_trees_close(r_s.params, r_l.params, atol=1e-5)


def test_ragged_dirichlet_sharded_matches_local():
    """Ragged N_i (masked batches, N_i/(B_i·N) weights) under psum
    aggregation — the heterogeneous-protocol path on the mesh."""
    z, y = _data(jax.random.PRNGKey(7), n=400)
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_dirichlet(z, y, I, jax.random.PRNGKey(8), alpha=0.3)
    assert len(set(int(c) for c in data.counts)) > 1   # genuinely ragged
    fl = _fl(batch_size=30)
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0)
    r_l = algorithms.algorithm1(psl, params0, data, fl, 50, **kw)
    r_s = algorithms.algorithm1(psl, params0, data, fl, 50,
                                topology=_shard_topo(), **kw)
    np.testing.assert_allclose(np.asarray(r_s.history["round_loss_est"]),
                               np.asarray(r_l.history["round_loss_est"]),
                               atol=1e-5)


def test_sample_sgd_sharded_matches_local():
    z, y = _data(jax.random.PRNGKey(0))
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, I)
    cfg = baselines.SGDConfig(lr_a=0.3, lr_alpha=0.3, local_batch=20,
                              local_steps=2)
    kw = dict(key=jax.random.PRNGKey(2), codec=make_codec("int8"))
    r_l = baselines.sample_sgd(psl, params0, data, cfg, 20, **kw)
    r_s = baselines.sample_sgd(psl, params0, data, cfg, 20,
                               topology=_shard_topo(), **kw)
    # atol 1e-4: int8 deltas hit weights undamped, so a rare quant-level
    # flip (see the algorithm-1 composition test) lands directly on a param
    _assert_trees_close(r_s.params, r_l.params, atol=1e-4)


def test_algorithm1_local_updates_sharded_matches_local():
    z, y = _data(jax.random.PRNGKey(0))
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, I)
    fl = _fl()
    kw = dict(local_steps=3, eval_fn=None, eval_every=0)
    r_l = algorithm1_local(psl, params0, data, fl, 30, jax.random.PRNGKey(2),
                           **kw)
    r_s = algorithm1_local(psl, params0, data, fl, 30, jax.random.PRNGKey(2),
                           topology=_shard_topo(), **kw)
    _assert_trees_close(r_s.params, r_l.params, atol=1e-5)


# ---------------------------------------------------------------------------
# accounting + state placement
# ---------------------------------------------------------------------------


def test_axis_bytes_metric_zero_local_positive_sharded():
    z, y = _data(jax.random.PRNGKey(0))
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, I)
    fl = _fl()
    topo = _shard_topo()
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0)
    r_l = algorithms.algorithm1(psl, params0, data, fl, 5, **kw)
    r_s = algorithms.algorithm1(psl, params0, data, fl, 5, topology=topo, **kw)
    assert float(r_l.history["round_axis_bytes"][0]) == 0.0
    dim = P * J + J * L
    expect = psum_axis_bytes(dim, topo.num_shards)
    assert float(r_s.history["round_axis_bytes"][0]) == float(expect)
    if topo.num_shards > 1:
        assert expect > 0
    # the client-boundary upload bytes are topology-invariant
    np.testing.assert_array_equal(
        np.asarray(r_l.history["round_upload_bytes"]),
        np.asarray(r_s.history["round_upload_bytes"]))


def test_psum_axis_bytes_closed_form():
    assert psum_axis_bytes(100, 1) == 0
    assert psum_axis_bytes(100, 8) == 2 * 7 * 4 * 100
    assert psum_axis_bytes(100, 8, with_value=True) == 2 * 7 * 4 * 101
    assert psum_axis_bytes(100, 4, num_streams=2) == 2 * psum_axis_bytes(100, 4)


def test_place_state_shards_ef_carry():
    topo = _shard_topo()
    state = CommCarry(opt=None, ef={"obj": ef_init_stacked(I, 40),
                                    "cons": ef_init_stacked(I, 40)})
    placed = topo.place_state(state)
    for leaf in jax.tree.leaves(placed.ef):
        assert leaf.shape == (I, 40)
        n_dev = len(leaf.sharding.device_set)
        assert n_dev == topo.num_shards
    # non-CommCarry states pass through untouched
    assert topo.place_state("opaque") == "opaque"
    assert LocalTopology().place_state(state) is state


# ---------------------------------------------------------------------------
# multi-device-only coverage (the dedicated CI job)
# ---------------------------------------------------------------------------


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8 (multi-device CI job)")
def test_eight_device_64_clients_full_composition():
    """The acceptance-criterion configuration at real distribution: I = 64
    clients over 8 devices, Algorithm 1, int8 + EF + partial participation."""
    z, y = _data(jax.random.PRNGKey(9), n=1280)
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, 64)
    topo = ShardedTopology(make_client_mesh(8))
    assert topo.num_shards == 8
    fl = _fl()
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0, participation=16,
              codec=make_codec("int8"))
    r_l = algorithms.algorithm1(psl, params0, data, fl, 30, **kw)
    r_s = algorithms.algorithm1(psl, params0, data, fl, 30, topology=topo,
                                **kw)
    np.testing.assert_allclose(np.asarray(r_s.history["round_loss_est"]),
                               np.asarray(r_l.history["round_loss_est"]),
                               atol=1e-5)
    _assert_trees_close(r_s.params, r_l.params, atol=1e-5)

"""Checkpointing, data pipeline, HLO cost parser, and roofline helpers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data.synthetic import (classification_dataset, make_batch_iterator,
                                  token_dataset)
from repro.roofline import hlo_cost
from repro.roofline.analysis import HW, roofline_terms


def test_checkpoint_roundtrip(tmp_path):
    key = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(key, (7, 3)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": jnp.float32(2.5)}}
    path = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(path, tree, step=42)
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = load_checkpoint(path, like)
    assert step == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_structure_mismatch(tmp_path):
    path = str(tmp_path / "c.msgpack")
    save_checkpoint(path, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        load_checkpoint(path, {"a": jnp.zeros(3), "b": jnp.zeros(2)})


def test_classification_dataset_deterministic():
    key = jax.random.PRNGKey(0)
    (z1, y1, l1), _ = classification_dataset(key, n=100, num_features=8,
                                             num_classes=3, test_n=10)
    (z2, y2, l2), _ = classification_dataset(key, n=100, num_features=8,
                                             num_classes=3, test_n=10)
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))
    assert y1.shape == (100, 3)


def test_token_dataset_and_iterator():
    toks = token_dataset(jax.random.PRNGKey(0), vocab_size=64, n_tokens=2000)
    assert toks.shape == (2000,) and int(toks.max()) < 64
    it = make_batch_iterator(toks, batch=4, seq=16, key=jax.random.PRNGKey(1))
    b = next(it)
    assert b["tokens"].shape == (4, 16) and b["targets"].shape == (4, 16)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["targets"][:, :-1]))


def test_hlo_cost_matches_xla_on_loop_free_module():
    def f(x, w1, w2):
        return jnp.sum(jnp.tanh(x @ w1) @ w2)

    args = [jax.ShapeDtypeStruct(s, jnp.float32)
            for s in [(64, 128), (128, 256), (256, 32)]]
    compiled = jax.jit(f).lower(*args).compile()
    got = hlo_cost.analyze(compiled.as_text())
    want_flops = 2 * 64 * 128 * 256 + 2 * 64 * 256 * 32
    assert abs(got["flops"] - want_flops) / want_flops < 1e-6
    # xla_cost_analysis normalizes the list/dict return drift across jax
    # versions; "bytes accessed" may be absent entirely on some backends.
    xla_bytes = hlo_cost.xla_cost_analysis(compiled).get("bytes accessed")
    if xla_bytes:
        assert abs(got["bytes"] - xla_bytes) / xla_bytes < 0.2


def test_hlo_cost_scan_multiplier():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        return jax.lax.scan(body, x, ws)[0].sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    got = hlo_cost.analyze(compiled.as_text())
    want = 12 * 2 * 64**3
    assert abs(got["flops"] - want) / want < 1e-6


def test_roofline_terms_bottleneck():
    t = roofline_terms({"flops": 197e12, "bytes accessed": 1e9}, 0)
    assert t["bottleneck"] == "compute" and abs(t["compute_s"] - 1.0) < 1e-9
    t = roofline_terms({"flops": 1e9, "bytes accessed": 819e9}, 0)
    assert t["bottleneck"] == "memory"
    t = roofline_terms({"flops": 0, "bytes accessed": 0}, 50e9)
    assert t["bottleneck"] == "collective" and abs(t["collective_s"] - 1.0) < 1e-9

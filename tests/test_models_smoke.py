"""Per-assigned-architecture smoke tests: a REDUCED variant of the same family
(2 layers, d_model <= 512, <= 4 experts) runs one forward/train step on CPU;
output shapes and finiteness asserted. Decode families also run one
serve_step against a fresh cache."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import FLConfig
from repro.configs.registry import ARCHS
from repro.core import optimizer
from repro.launch.train import make_train_step
from repro.models import get_model

B, S = 2, 64


def _batch(c, key):
    if c.family == "mlp":
        return {"features": jax.random.normal(key, (B, c.d_model)),
                "labels_onehot": jax.nn.one_hot(jnp.array([1, 2]), c.vocab_size)}
    if c.family == "vlm":
        st = S - c.num_prefix_tokens
        return {"tokens": jnp.ones((B, st), jnp.int32),
                "targets": jnp.ones((B, st), jnp.int32),
                "prefix_embeddings": jax.random.normal(
                    key, (B, c.num_prefix_tokens, c.d_model)).astype(c.dtype)}
    if c.family == "audio":
        return {"frame_embeddings": jax.random.normal(key, (B, S, c.d_model)).astype(c.dtype),
                "tokens": jnp.ones((B, S // 4), jnp.int32),
                "targets": jnp.ones((B, S // 4), jnp.int32)}
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "targets": jnp.ones((B, S), jnp.int32)}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_train_step(arch):
    c = ARCHS[arch].smoke()
    model = get_model(c)
    key = jax.random.PRNGKey(0)
    params = model.init(key, c)
    batch = _batch(c, key)

    loss = model.loss_fn(params, batch, c)
    assert loss.shape == () and bool(jnp.isfinite(loss)), f"{arch}: bad loss {loss}"

    # one SSCA train step
    fl = FLConfig(tau=0.2, l2_lambda=1e-5)
    state = optimizer.ssca_init(params)
    step = jax.jit(make_train_step(model, c, fl))
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), f"{arch}: NaN params"
    # params actually moved
    moved = any(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
                for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(params)))
    assert moved, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", [a for a in sorted(ARCHS)
                                  if ARCHS[a].family != "mlp"])
def test_smoke_decode_step(arch):
    c = ARCHS[arch].smoke()
    model = get_model(c)
    assert model.has_decode
    key = jax.random.PRNGKey(0)
    params = model.init(key, c)
    cache = model.init_cache(c, B, S)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, tok, jnp.int32(0), c)
    assert logits.shape[0] == B and logits.shape[-1] == c.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "zamba2-1.2b", "xlstm-1.3b",
                                  "glm4-9b-swa", "seamless-m4t-medium"])
def test_prefill_then_decode_consistency(arch):
    """decode_step after prefill must reproduce the full-forward logits of
    the extended sequence (KV-cache/SSM-state correctness)."""
    c = ARCHS[arch].smoke()
    model = get_model(c)
    key = jax.random.PRNGKey(1)
    params = model.init(key, c)
    s = 32
    toks = jax.random.randint(key, (B, s + 1), 0, c.vocab_size)
    batch = {"tokens": toks[:, :s]}
    if c.family == "audio":
        batch["frame_embeddings"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, 4 * s, c.d_model)).astype(c.dtype)

    logits_p, cache = model.prefill(params, batch, c)

    # full forward over s+1 tokens: compare last-position logits
    from repro.launch.serve import grow_cache
    cache = grow_cache(cache, 4)
    pos = jnp.asarray(s, jnp.int32)
    logits_d, _ = model.decode_step(params, cache, toks[:, s:s + 1], pos, c)

    batch2 = dict(batch, tokens=toks[:, :s + 1])
    logits_f, _ = model.prefill(params, batch2, c)

    import numpy as np
    np.testing.assert_allclose(
        np.asarray(logits_d[:, -1, :], np.float32),
        np.asarray(logits_f[:, -1, :], np.float32), rtol=5e-2, atol=5e-2)

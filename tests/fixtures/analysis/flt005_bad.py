"""FLT005 fixture: f64 and dtype-less constructors in kernel-scoped code."""
# flint: scope=kernel
import jax.numpy as jnp
import numpy as np


def encode(x):
    scales = jnp.zeros(x.shape[0])            # dtype-less: weak default
    table = jnp.arange(256)                   # dtype-less: int32/int64 drift
    acc = x.astype(jnp.float64)               # f64 doubles bytes-on-wire
    wide = np.float64(1.0)
    return scales, table, acc * wide

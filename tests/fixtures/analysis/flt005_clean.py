"""FLT005 clean twin: every buffer dtype pinned."""
# flint: scope=kernel
import jax.numpy as jnp


def encode(x):
    scales = jnp.zeros((x.shape[0],), jnp.float32)
    table = jnp.arange(256, dtype=jnp.int32)
    acc = x.astype(jnp.float32)
    return scales, table, acc

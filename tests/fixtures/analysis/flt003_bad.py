"""FLT003 fixture: host entropy/clock calls inside a jitted scope."""
import random
import time

import jax
import jax.numpy as jnp


@jax.jit
def noisy_step(x):
    jitter = random.random()          # frozen into the trace as a constant
    stamp = time.time()               # likewise
    return x * jitter + stamp

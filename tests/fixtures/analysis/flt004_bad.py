"""FLT004 fixture: imports/uses of the deprecated shims."""
from repro.core.privacy import DPConfig, dp_sample_round
from repro.launch import feature_dist


def train(psl, params, data, key, dp):
    g, q = dp_sample_round(psl, params, data, key, 32, dp)
    return g, q


def make_round(mesh, head_loss, client_h):
    return feature_dist.make_feature_round(mesh, head_loss, client_h)

"""FLT006 clean twin: None defaults, tuple/dict pytree carries."""
import jax
import jax.numpy as jnp


def accumulate(x, history=None):
    history = [] if history is None else history
    history.append(x)
    return history


def configure(opts=None):
    return {} if opts is None else opts


def run(xs):
    def body(carry, x):
        total, count = carry
        return (total + x, count + 1), x

    return jax.lax.scan(body, (jnp.zeros(()), jnp.zeros((), jnp.int32)), xs)

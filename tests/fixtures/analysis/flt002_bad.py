"""FLT002 fixture: key reuse, loop reuse, and positional per-client split."""
import jax
import jax.numpy as jnp


def straight_line_reuse(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.normal(key, (4,))          # same key, repeated randomness
    return a + b


def loop_reuse(key, n):
    total = jnp.zeros(())
    for _ in range(n):
        total += jax.random.uniform(key)      # key never reassigned in loop
    return total


def positional_client_keys(key, num_clients):
    return jax.random.split(key, num_clients)  # positional, not stable-id

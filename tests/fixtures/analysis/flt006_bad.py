"""FLT006 fixture: mutable defaults and a non-pytree scan carry."""
import jax
import jax.numpy as jnp


def accumulate(x, history=[]):        # shared across calls, leaks tracers
    history.append(x)
    return history


def configure(opts={}):               # mutable default dict
    return opts


def run(xs):
    def body(carry, x):
        total, seen = carry
        return (total + x, seen), x

    # a set in the carry is not a pytree: fails at trace time
    return jax.lax.scan(body, (jnp.zeros(()), {0}), xs)

"""FLT001 fixture: host syncs inside a scan-rooted round body."""
import jax
import jax.numpy as jnp
import numpy as np


def round_body(carry, x):
    v = carry + x
    loss = v.sum().item()             # device->host sync in the scan body
    arr = np.asarray(v)               # host materialization
    scale = float(jnp.max(v))         # concretizes a tracer
    return carry + scale, {"loss": loss, "arr": arr.sum()}


def run(xs):
    return jax.lax.scan(round_body, jnp.zeros(()), xs)

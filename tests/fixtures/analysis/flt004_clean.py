"""FLT004 clean twin: the replacement APIs."""
from repro.core import fed
from repro.core.privacy import DPConfig
from repro.core.topology import ShardedTopology


def train(psl, params, data, key, dp):
    grad_est, val_est, up = fed.sample_round(psl, params, data, key, 32,
                                             dp=dp)
    return grad_est, up["q_grad_sums"]


def make_round(mesh, params, data, key, head_loss, client_h):
    topo = ShardedTopology(mesh, axes=("model",))
    return fed.feature_round(params, data, key, 32, head_loss, client_h,
                             topology=topo)

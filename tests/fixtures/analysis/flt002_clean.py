"""FLT002 clean twin: fold_in-derived keys, split-and-reassign loops."""
import jax
import jax.numpy as jnp


def fresh_keys(key):
    a = jax.random.normal(jax.random.fold_in(key, 0), (4,))
    b = jax.random.normal(jax.random.fold_in(key, 1), (4,))
    return a + b


def loop_fold_in(key, n):
    total = jnp.zeros(())
    for i in range(n):
        total += jax.random.uniform(jax.random.fold_in(key, i))
    return total


def loop_split(key, n):
    total = jnp.zeros(())
    for _ in range(n):
        key, sub = jax.random.split(key)      # reassigned each iteration
        total += jax.random.uniform(sub)
    return total


def stable_client_keys(key, ids):
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(ids)

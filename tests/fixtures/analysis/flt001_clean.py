"""FLT001 clean twin: the same shape of program, all readouts traced or
host-side (outside any jit entry)."""
import jax
import jax.numpy as jnp
import numpy as np


def round_body(carry, x):
    v = carry + x
    loss = jnp.sum(v)                 # stays a traced array
    scale = jnp.max(v)
    return carry + scale, {"loss": loss}


def run(xs):
    return jax.lax.scan(round_body, jnp.zeros(()), xs)


def report(history):
    # host code: never passed to a jit entry, so host ops are fine here
    return float(np.asarray(history["loss"]).mean())

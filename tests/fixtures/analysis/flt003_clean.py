"""FLT003 clean twin: host clock only in host scopes, device randomness
from jax.random keys."""
import time

import jax
import jax.numpy as jnp


@jax.jit
def noisy_step(x, key):
    jitter = jax.random.uniform(key)
    return x * jitter


def timed_run(x, key):
    t0 = time.time()                  # host timing around the dispatch: fine
    out = noisy_step(x, key)
    out.block_until_ready()
    return out, time.time() - t0

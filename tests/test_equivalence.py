"""Remark 2: the Algorithm-1 example IS momentum SGD with diminishing stepsize
(eqs. (11)-(12)) — validated as an exact iterate-by-iterate match."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import fed, optimizer
from repro.data.synthetic import classification_dataset
from repro.models import mlp


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    (z, y, _), _ = classification_dataset(key, n=1000, num_features=24,
                                          num_classes=5, test_n=10)
    params0 = mlp.init(jax.random.PRNGKey(1), 24, 12, 5)
    data = fed.partition_samples(z, y, 5)
    return params0, data


def psl(p, z, y):
    return mlp.per_sample_loss(p, z, y)


@pytest.mark.parametrize("lam", [0.0, 1e-3])
def test_ssca_equals_momentum_form(setup, lam):
    params0, data = setup
    fl = FLConfig(batch_size=20, a1=0.9, a2=0.5, alpha_rho=0.1,
                  alpha_gamma=0.6, tau=0.2, l2_lambda=lam)
    s1 = optimizer.ssca_init(params0)
    s2 = optimizer.momentum_form_init(params0)
    key = jax.random.PRNGKey(3)
    for _ in range(25):
        key, sub = jax.random.split(key)
        g1, _, _ = fed.sample_round(psl, s1.params, data, sub, fl.batch_size)
        g2, _, _ = fed.sample_round(psl, s2.params, data, sub, fl.batch_size)
        s1 = optimizer.ssca_step(s1, g1, fl)
        s2 = optimizer.momentum_form_step(s2, g2, fl)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_folded_lambda_equals_separate_beta_buffer(setup):
    """DESIGN.md §2: one folded buffer D = A + 2λβ reproduces the paper's
    (A, β) pair of (35)-(38) exactly."""
    params0, data = setup
    lam, tau = 1e-3, 0.2
    fl = FLConfig(batch_size=20, tau=tau, l2_lambda=lam)
    s = optimizer.ssca_init(params0)

    # faithful two-buffer version
    a_buf = jax.tree.map(lambda x: jnp.zeros_like(x), params0)
    beta = jax.tree.map(lambda x: jnp.zeros_like(x), params0)
    w = params0
    key = jax.random.PRNGKey(7)
    from repro.core import schedules
    for t in range(1, 16):
        key, sub = jax.random.split(key)
        g, _, _ = fed.sample_round(psl, w, data, sub, fl.batch_size)
        rho = 1.0 if t == 1 else schedules.rho(t, fl.a1, fl.alpha_rho)
        gam = schedules.gamma(t, fl.a2, fl.alpha_gamma)
        a_buf = jax.tree.map(lambda ab, gg, ww: (1 - rho) * ab + rho * (gg - 2 * tau * ww),
                             a_buf, g, w)
        beta = jax.tree.map(lambda bb, ww: (1 - rho) * bb + rho * ww, beta, w)
        wbar = jax.tree.map(lambda ab, bb: -(ab + 2 * lam * bb) / (2 * tau), a_buf, beta)
        w = jax.tree.map(lambda ww, wb: (1 - gam) * ww + gam * wb, w, wbar)

        g2, _, _ = fed.sample_round(psl, s.params, data, sub, fl.batch_size)
        s = optimizer.ssca_step(s, g2, fl)

    for a, b in zip(jax.tree.leaves(w), jax.tree.leaves(s.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)

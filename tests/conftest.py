import jax
import pytest

# Smoke tests and benches run on the single real CPU device. The 512-device
# override lives ONLY in launch/dryrun.py (per the brief).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)

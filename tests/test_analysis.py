"""Tests for the static-analysis subsystem (DESIGN.md §16): each FLT rule
against its committed bad/clean fixture pair, suppression comments, CLI
exit codes and JSON report, the jaxpr contract checkers (positive run over
a slice of the config matrix + synthetic negative controls per checker),
and the retrace sentinel (clean reuse vs a provoked recompile).

The FULL 16-config contract matrix runs in CI via
`python -m repro.analysis` (the analysis job) — here we keep a
representative 4-config diagonal so tier-1 stays fast."""
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import contracts, lint, retrace
from repro.analysis.__main__ import main as analysis_main
from repro.configs.base import FLConfig
from repro.core import rounds

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "fixtures", "analysis")

RULE_CODES = ("FLT001", "FLT002", "FLT003", "FLT004", "FLT005", "FLT006")


def _lint_fixture(name):
    return lint.lint_paths([os.path.join(FIXTURES, name)], root=REPO)


# ---------------------------------------------------------------------------
# layer 1: the lint rules, fixture pair per rule
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("code", RULE_CODES)
def test_rule_flags_bad_fixture(code):
    res = _lint_fixture(f"{code.lower()}_bad.py")
    assert res.exit_code == 1
    codes = {f.code for f in res.findings}
    assert codes == {code}, f"expected only {code}, got {codes}"


@pytest.mark.parametrize("code", RULE_CODES)
def test_rule_passes_clean_twin(code):
    res = _lint_fixture(f"{code.lower()}_clean.py")
    assert res.exit_code == 0, [f.render() for f in res.findings]


def test_bad_fixtures_flag_expected_lines():
    res = _lint_fixture("flt002_bad.py")
    lines = sorted(f.line for f in res.findings)
    assert len(lines) == 3          # straight-line, loop, positional split
    msgs = " ".join(f.message for f in res.findings)
    assert "fold_in the loop index" in msgs
    assert "client_keys" in msgs


def test_suppression_comment(tmp_path):
    bad = open(os.path.join(FIXTURES, "flt001_bad.py")).read()
    patched = bad.replace(".item()             #", ".item()  # flint: disable=FLT001 #")
    p = tmp_path / "suppressed.py"
    p.write_text(patched)
    res = lint.lint_paths([p], root=tmp_path)
    assert all(f.line != 9 for f in res.findings if f.code == "FLT001")
    assert any(s.line == 9 and s.code == "FLT001" and s.suppressed
               for s in res.suppressed)


def test_suppression_without_code_disables_all(tmp_path):
    p = tmp_path / "all_off.py"
    p.write_text(
        "import jax, time\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x * time.time()  # flint: disable\n")
    res = lint.lint_paths([p], root=tmp_path)
    assert res.exit_code == 0
    assert len(res.suppressed) == 1


def test_repo_is_lint_clean_with_zero_core_suppressions():
    res = lint.lint_paths([os.path.join(REPO, "src", "repro"),
                           os.path.join(REPO, "benchmarks")], root=REPO)
    assert res.exit_code == 0, "\n".join(f.render() for f in res.findings)
    core = os.path.join("src", "repro", "core")
    core_suppressed = [s for s in res.suppressed if core in s.path]
    assert not core_suppressed, (
        "src/repro/core must pass with zero suppressions: "
        + "\n".join(s.render() for s in core_suppressed))


def test_reachability_does_not_flag_host_code():
    # obs/sinks host-side .item() and benchmark timing loops must NOT flag:
    # they are never passed to a jit entry
    res = lint.lint_paths([os.path.join(REPO, "src", "repro", "obs"),
                           os.path.join(REPO, "benchmarks")], root=REPO)
    assert not [f for f in res.findings if f.code in ("FLT001", "FLT003")]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_exit_codes_per_fixture():
    for code in RULE_CODES:
        bad = os.path.join(FIXTURES, f"{code.lower()}_bad.py")
        clean = os.path.join(FIXTURES, f"{code.lower()}_clean.py")
        assert analysis_main([bad]) == 1
        assert analysis_main([clean]) == 0


def test_cli_json_report(tmp_path, capsys):
    out = tmp_path / "report.json"
    rc = analysis_main([os.path.join(FIXTURES, "flt004_bad.py"),
                        "--format", "json", "-o", str(out)])
    assert rc == 1
    report = json.loads(out.read_text())
    assert report["tool"] == "repro.analysis"
    assert report["lint"]["num_findings"] > 0
    assert all(f["code"] == "FLT004" for f in report["lint"]["findings"])
    # explicit paths skip the contract matrix
    assert report["contracts"] is None


# ---------------------------------------------------------------------------
# layer 2: jaxpr contract checkers
# ---------------------------------------------------------------------------

# diagonal through the matrix: every engine/topology/codec/dp value appears
_DIAGONAL = [
    ("dense/local/identity/nodp", "dense", "local", "identity", False),
    ("dense/sharded/int8/dp", "dense", "sharded", "int8", True),
    ("cohort/local/int8/nodp", "cohort", "local", "int8", False),
    ("cohort/sharded/identity/dp", "cohort", "sharded", "identity", True),
]


@pytest.mark.parametrize("cfg", _DIAGONAL, ids=[c[0] for c in _DIAGONAL])
def test_contract_config_passes(cfg):
    violations = contracts.run_config(*cfg, execute=False)
    assert not violations, "\n".join(v.render() for v in violations)


def test_contract_matrix_covers_full_product():
    names = [c[0] for c in contracts.matrix_configs()]
    assert len(names) == 16
    assert len(set(names)) == 16
    for engine in ("dense", "cohort"):
        for topo in ("local", "sharded"):
            for codec in ("identity", "int8"):
                for dp in ("dp", "nodp"):
                    assert f"{engine}/{topo}/{codec}/{dp}" in names


def test_obs_tap_contract():
    assert contracts.check_obs_tap() == []


def test_scan_pure_catches_callback():
    def tap(x):
        return None

    def body(c, x):
        jax.experimental.io_callback(tap, None, x, ordered=False)
        return c + x, x

    closed = jax.make_jaxpr(
        lambda c, xs: jax.lax.scan(body, c, xs))(
            jnp.zeros(()), jnp.arange(3.0))
    body_jaxpr = contracts.find_scan_body(closed)
    out = contracts.check_scan_pure(body_jaxpr)
    assert out and "io_callback" in out[0]


def test_dp_before_encode_catches_swapped_order():
    # encode-then-noise: the int8 convert appears BEFORE the gaussian draw
    def body(c, key):
        enc = (c * 127.0).astype(jnp.int8)
        noisy = enc.astype(jnp.float32) + jax.random.normal(key, c.shape)
        return noisy, enc

    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    closed = jax.make_jaxpr(
        lambda c, ks: jax.lax.scan(body, c, ks))(jnp.zeros((4,)), keys)
    body_jaxpr = contracts.find_scan_body(closed)
    out = contracts.check_dp_before_encode(body_jaxpr, dp_on=True, int8=True)
    assert out and "does not precede" in out[0]


def test_dp_before_encode_catches_missing_and_spurious_noise():
    def pure_body(c, x):
        return c + x, x

    closed = jax.make_jaxpr(
        lambda c, xs: jax.lax.scan(pure_body, c, xs))(
            jnp.zeros(()), jnp.arange(3.0))
    body_jaxpr = contracts.find_scan_body(closed)
    assert contracts.check_dp_before_encode(body_jaxpr, dp_on=True,
                                            int8=False)
    assert not contracts.check_dp_before_encode(body_jaxpr, dp_on=False,
                                                int8=False)


def test_collective_axes_catches_undeclared_axis():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_client_mesh

    mesh = make_client_mesh(axis="data")

    def summed(x):
        return jax.lax.psum(x, "data")

    fn = shard_map(summed, mesh=mesh, in_specs=P("data"), out_specs=P())
    closed = jax.make_jaxpr(fn)(jnp.zeros((jax.device_count(),)))
    assert contracts.check_collective_axes(closed.jaxpr, allowed=())
    assert not contracts.check_collective_axes(closed.jaxpr,
                                               allowed=("data",))


def test_wire_dtypes_catches_spec_violation():
    from repro.comm.codecs import QuantEncoded

    class BadCodec:
        def encode(self, x, key=None):
            # values must be int8 per the quantizer wire spec
            return QuantEncoded(values=x, scales=jnp.ones((1,), jnp.float32))

    out = contracts.check_wire_dtypes(BadCodec(), dim=8)
    assert out and "int8" in out[0]

    from repro.comm.codecs import make_codec
    assert contracts.check_wire_dtypes(make_codec("int8"), dim=256) == []
    assert contracts.check_wire_dtypes(make_codec("identity"), dim=8) == []
    assert contracts.check_wire_dtypes(None, dim=8) == []


# ---------------------------------------------------------------------------
# retrace sentinel
# ---------------------------------------------------------------------------


def _toy_step():
    def step(state, inp):
        return state + inp.rho, {"m": state}
    return step


def test_retrace_sentinel_clean_on_stable_shapes():
    fl = FLConfig()
    step = _toy_step()
    state = jnp.zeros(())
    inputs = rounds.make_inputs(fl, 1, 4, jax.random.PRNGKey(0))
    with retrace.RetraceSentinel() as sentinel:
        rounds.scan_rounds(step, state, inputs)
        rounds.scan_rounds(step, state, inputs)   # cache hit, no retrace
    assert sentinel.ok, sentinel.render_text()
    assert sentinel.report()["tracked"] == 1


def test_retrace_sentinel_catches_deliberate_recompile():
    fl = FLConfig()
    step = _toy_step()
    state = jnp.zeros(())
    with retrace.RetraceSentinel() as sentinel:
        # same step fn, different K -> different input shapes -> retrace
        rounds.scan_rounds(step, state,
                           rounds.make_inputs(fl, 1, 4, jax.random.PRNGKey(0)))
        rounds.scan_rounds(step, state,
                           rounds.make_inputs(fl, 1, 5, jax.random.PRNGKey(0)))
    assert not sentinel.ok
    assert sentinel.violations[0].compiles == 2
    assert "retrace" in sentinel.render_text()


def test_retrace_sentinel_restores_patches():
    orig_scan, orig_step = rounds._scan_jit, rounds._step_jit
    with retrace.RetraceSentinel():
        assert rounds._scan_jit is not orig_scan
    assert rounds._scan_jit is orig_scan
    assert rounds._step_jit is orig_step

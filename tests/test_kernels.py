"""Per-kernel allclose vs the pure-jnp oracle: shape/dtype sweeps, all in
Pallas interpret mode (the kernel body executes in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssca_update import ssca_update_pallas


@pytest.mark.parametrize("shape", [(4, 128), (3, 7, 256), (1, 1024), (2, 37, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, shape).astype(dtype)
    sc = (jax.random.normal(jax.random.fold_in(key, 1), (shape[-1],)) * 0.1)
    got = rmsnorm_pallas(x, sc, interpret=True, block_rows=16)
    want = ref.rmsnorm_ref(x, sc)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("n", [17, 1000, 4096, 70000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssca_update_matches_ref(n, dtype):
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (n,)).astype(dtype)
    buf = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    g = jax.random.normal(jax.random.fold_in(key, 2), (n,)).astype(dtype)
    rho, gamma, tau, lam = 0.7, 0.25, 0.2, 1e-4
    gw, gb = ssca_update_pallas(w, buf, g, rho, gamma, tau, lam,
                                block=8192, interpret=True)
    ww, wb = ref.ssca_update_ref(w, buf, g, rho, gamma, tau, lam)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(gw, np.float32),
                               np.asarray(ww, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(wb), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,h,kv,sq,sk,d", [
    (1, 4, 4, 128, 128, 64),       # MHA square
    (2, 8, 2, 128, 128, 64),       # GQA
    (1, 8, 1, 64, 256, 128),       # MQA, right-aligned decode-ish window
    (1, 4, 4, 256, 256, 32),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 96), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, h, kv, sq, sk, d, causal, window, dtype):
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (b, h, sq, d)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, kv, sk, d)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, kv, sk, d)).astype(dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_attention_blocks_fully_masked_rows():
    """Sliding window that masks whole K tiles must not produce NaNs."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 2, 256, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 256, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 256, 32))
    got = flash_attention_pallas(q, k, v, causal=True, window=32,
                                 block_q=64, block_k=64, interpret=True)
    assert bool(jnp.all(jnp.isfinite(got)))
    want = ref.flash_attention_ref(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_ops_dispatch_ref_on_cpu():
    from repro.kernels import ops
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (4, 64))
    sc = jnp.zeros((64,))
    np.testing.assert_allclose(np.asarray(ops.rmsnorm(x, sc)),
                               np.asarray(ref.rmsnorm_ref(x, sc)), rtol=1e-6)


@pytest.mark.parametrize("n", [17, 1000, 4096, 70000])
@pytest.mark.parametrize("qmax", [127, 7])
def test_quantize_kernel_matches_ref(n, qmax):
    """Fused quantize-dequantize kernel == the comm/codecs.py math exactly
    (same PRNG bits in on the portable path -> same wire values out)."""
    from repro.kernels.quantize import stochastic_quantize_pallas
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (n,)) * 3.0
    chunk = 256
    num_chunks = -(-n // chunk)
    bits = jax.random.bits(jax.random.fold_in(key, 1),
                           (num_chunks * chunk,), jnp.uint32)
    v_r, s_r, xh_r = ref.stochastic_quantize_ref(x, bits, qmax, chunk)
    v_p, s_p, xh_p = stochastic_quantize_pallas(x, qmax, chunk, bits=bits,
                                                block_rows=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(v_p), np.asarray(v_r))
    np.testing.assert_array_equal(np.asarray(s_p), np.asarray(s_r))
    np.testing.assert_array_equal(np.asarray(xh_p), np.asarray(xh_r))
    # per-chunk absmax scales are deterministic and exact
    pad = num_chunks * chunk - n
    xc = np.pad(np.asarray(x), (0, pad)).reshape(num_chunks, chunk)
    np.testing.assert_allclose(np.asarray(s_p),
                               np.abs(xc).max(axis=1) / qmax, rtol=1e-6)


def test_quantize_kernel_through_codec_pallas_impl():
    """The codec's impl="pallas" path (interpret mode) is bit-identical to
    impl="ref" — both consume the same jax.random bits."""
    from repro.comm.codecs import StochasticQuantizer
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (3000,))
    r = StochasticQuantizer(bits=8, chunk=256, impl="ref")
    p = StochasticQuantizer(bits=8, chunk=256, impl="pallas", interpret=True)
    enc_r, xh_r = r.roundtrip(x, jax.random.fold_in(key, 1))
    enc_p, xh_p = p.roundtrip(x, jax.random.fold_in(key, 1))
    np.testing.assert_array_equal(np.asarray(enc_p.values),
                                  np.asarray(enc_r.values))
    np.testing.assert_array_equal(np.asarray(enc_p.scales),
                                  np.asarray(enc_r.scales))
    np.testing.assert_array_equal(np.asarray(xh_p), np.asarray(xh_r))

"""Expert-parallel shard_map MoE (§Perf iteration 14): loss parity with the
GSPMD scatter path on a real (data, model) mesh, in a subprocess (needs 8
virtual devices). Without a mesh it must fall back to the GSPMD path."""
import os
import subprocess
import sys
import textwrap

import dataclasses
import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS
from repro.models import get_model

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_fallback_without_mesh_matches_gspmd_path():
    c0 = ARCHS["qwen3-moe-30b-a3b"].smoke()
    c1 = dataclasses.replace(c0, moe_sharding="expert_parallel")
    m = get_model(c0)
    key = jax.random.PRNGKey(0)
    params = m.init(key, c0)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, c0.vocab_size),
             "targets": jnp.ones((2, 32), jnp.int32)}
    assert abs(float(m.loss_fn(params, batch, c0))
               - float(m.loss_fn(params, batch, c1))) < 1e-6


def test_expert_parallel_on_mesh_subprocess():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs.registry import ARCHS
        from repro.models import get_model

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        c0 = ARCHS["qwen3-moe-30b-a3b"].smoke()
        c1 = dataclasses.replace(c0, moe_sharding="expert_parallel")
        m = get_model(c0)
        key = jax.random.PRNGKey(0)
        params = m.init(key, c0)
        batch = {"tokens": jax.random.randint(key, (4, 32), 0, c0.vocab_size),
                 "targets": jnp.ones((4, 32), jnp.int32)}
        l0 = float(m.loss_fn(params, batch, c0))
        with mesh:
            pspec = jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s),
                                 m.param_specs(c1, "train"),
                                 is_leaf=lambda x: isinstance(x, P))
            bspec = jax.tree.map(
                lambda _: jax.sharding.NamedSharding(mesh, P(("data",))), batch)
            fn = jax.jit(lambda p, b: m.loss_fn(p, b, c1),
                         in_shardings=(pspec, bspec))
            l1 = float(fn(params, batch))
            g = jax.jit(jax.grad(lambda p: m.loss_fn(p, batch, c1)))(params)
        assert abs(l0 - l1) < 5e-2, (l0, l1)   # capacity-drop ordering differs
        gn = sum(float(jnp.sum(jnp.abs(x.astype(jnp.float32))))
                 for x in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0
        print("OK", l0, l1)
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "OK" in proc.stdout

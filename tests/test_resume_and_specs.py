"""Framework-property tests: checkpoint/resume bit-equivalence for SSCA
training (params + surrogate state), streaming-data rounds (paper footnote 3),
and fit_specs invariants (hypothesis)."""

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from jax.sharding import PartitionSpec as P

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs.base import FLConfig
from repro.core import fed, optimizer
from repro.data.synthetic import classification_dataset
from repro.models import mlp


def test_ssca_checkpoint_resume_equivalence(tmp_path):
    """Saving (params, surrogate buffer, t) at round 10 and resuming must
    reproduce the uninterrupted run exactly — the surrogate state is part of
    the algorithm, not a disposable optimizer detail."""
    key = jax.random.PRNGKey(0)
    (z, y, _), _ = classification_dataset(key, n=1000, num_features=16,
                                          num_classes=3, test_n=10)
    data = fed.partition_samples(z, y, 2)
    params0 = mlp.init(jax.random.PRNGKey(1), 16, 8, 3)
    fl = FLConfig(batch_size=16, tau=0.2, l2_lambda=1e-4, alpha_gamma=0.6)

    def psl(p, zz, yy):
        return mlp.per_sample_loss(p, zz, yy)

    def run(state, start, stop, key):
        for t in range(start, stop):
            g, _, _ = fed.sample_round(psl, state.params, data,
                                       jax.random.fold_in(key, t), fl.batch_size)
            state = optimizer.ssca_step(state, g, fl)
        return state

    key_r = jax.random.PRNGKey(2)
    full = run(optimizer.ssca_init(params0), 0, 20, key_r)

    half = run(optimizer.ssca_init(params0), 0, 10, key_r)
    path = str(tmp_path / "state.msgpack")
    save_checkpoint(path, half, step=10)
    restored, step = load_checkpoint(path, optimizer.ssca_init(params0))
    assert step == 10
    resumed = run(optimizer.SSCAState(*restored), 10, 20, key_r)

    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_streaming_data_rounds():
    """Footnote 3: SSCA over streaming data — each round sees fresh samples
    (never revisited); the surrogate's incremental averaging still converges."""
    key = jax.random.PRNGKey(3)
    params = mlp.init(jax.random.PRNGKey(1), 16, 8, 3)
    fl = FLConfig(batch_size=64, tau=0.2, l2_lambda=1e-5, a1=0.9, a2=0.5,
                  alpha_rho=0.1, alpha_gamma=0.6)
    state = optimizer.ssca_init(params)
    protos = jax.random.normal(jax.random.fold_in(key, 9), (3, 16)) * 0.5
    losses = []
    for t in range(200):
        kt = jax.random.fold_in(key, t)          # a fresh stream batch
        lab = jax.random.randint(kt, (fl.batch_size,), 0, 3)
        zb = protos[lab] + jax.random.normal(
            jax.random.fold_in(kt, 1), (fl.batch_size, 16)) * 0.5
        yb = jax.nn.one_hot(lab, 3)
        loss, g = jax.value_and_grad(
            lambda p: jnp.mean(mlp.per_sample_loss(p, zb, yb)))(state.params)
        state = optimizer.ssca_step(state, g, fl)
        if t % 40 == 0:
            losses.append(float(loss))
    assert losses[-1] < losses[0] and np.isfinite(losses).all()


@settings(deadline=None, max_examples=60)
@given(st.integers(1, 4), st.lists(st.integers(1, 6), min_size=1, max_size=4),
       st.integers(0, 3))
def test_fit_specs_always_lowerable(nspec, dim_factors, seed):
    """fit_specs must always return a spec whose every entry divides its dim
    and never assigns one mesh axis twice."""
    import os
    from repro.launch.mesh import fit_specs

    # fake mesh object with axis sizes
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (4, 2)
    rng = np.random.RandomState(seed)
    dims = tuple(int(f) * int(rng.choice([1, 2, 4])) for f in dim_factors)
    entries = list(rng.choice(["data", "model", None], size=min(nspec, len(dims))))
    spec = P(*entries)
    shp = jax.ShapeDtypeStruct(dims, jnp.float32)
    fitted = fit_specs(spec, shp, FakeMesh)
    sizes = {"data": 4, "model": 2}
    used = []
    for i, e in enumerate(fitted):
        if e is None:
            continue
        names = (e,) if isinstance(e, str) else e
        n = 1
        for nm in names:
            n *= sizes[nm]
            used.append(nm)
        assert dims[i] % n == 0, (fitted, dims)
    assert len(used) == len(set(used)), f"axis used twice: {fitted}"

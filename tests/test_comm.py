"""Communication-compression subsystem (repro.comm, DESIGN.md §10): codec
roundtrips + wire-byte accounting, quantizer unbiasedness, error-feedback
invariants, codec state round-tripping through the lax.scan carry (scan ==
loop with compression on), and the headline acceptance claim — int8
stochastic quantization tracks the uncompressed quickstart run within 2%
final loss at >= 3.5x fewer upload bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (CommLedger, accounting, codecs, error_feedback,
                        make_codec)
from repro.configs.base import FLConfig
from repro.core import algorithms, baselines, fed
from repro.core.baselines import SGDConfig
from repro.data.synthetic import classification_dataset
from repro.models import mlp

P, J, L = 12, 6, 3


def _data(key, n=240):
    z = jax.random.normal(key, (n, P))
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, L)
    return z, jax.nn.one_hot(lab, L)


def _fl(**kw):
    base = dict(batch_size=20, a1=0.9, a2=0.5, alpha_rho=0.1,
                alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5)
    base.update(kw)
    return FLConfig(**base)


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------


def test_identity_codec_is_exact():
    x = jax.random.normal(jax.random.PRNGKey(0), (301,))
    enc, xhat = codecs.Identity().roundtrip(x)
    np.testing.assert_array_equal(np.asarray(xhat), np.asarray(x))
    assert codecs.Identity().nbytes(301) == 4 * 301


@pytest.mark.parametrize("bits", [8, 4])
def test_quantizer_error_bounded_by_chunk_scale(bits):
    """|decode(encode(x)) - x| <= scale per element (one quantization level)."""
    sq = codecs.StochasticQuantizer(bits=bits, chunk=64)
    x = jax.random.normal(jax.random.PRNGKey(1), (1000,)) * 5.0
    enc, xhat = sq.roundtrip(x, jax.random.PRNGKey(2))
    err = np.abs(np.asarray(xhat - x)).reshape(-1)
    per_chunk = np.repeat(np.asarray(enc.scales), 64)[:1000]
    assert (err <= per_chunk + 1e-7).all()
    assert enc.values.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(enc.values))) <= sq.qmax


def test_quantizer_unbiased_mean():
    """CLT check of E[decode(encode(x))] == x: the mean over M independent
    encodings deviates by O(scale/sqrt(M))."""
    sq = codecs.StochasticQuantizer(bits=8, chunk=64)
    x = jax.random.normal(jax.random.PRNGKey(3), (256,)) * 2.0
    keys = jax.random.split(jax.random.PRNGKey(4), 4000)
    xh = jax.vmap(lambda k: sq.roundtrip(x, k)[1])(keys)
    bias = np.abs(np.asarray(jnp.mean(xh, axis=0) - x))
    # per-element rounding variance <= scale^2/4; 6-sigma CLT band
    tol = 6 * float(jnp.max(sq.encode(x, keys[0]).scales)) * 0.5 / np.sqrt(4000)
    assert bias.max() < tol


def test_topk_keeps_largest_and_frac1_is_exact():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0, 1.0, -0.3])
    tk = codecs.TopK(frac=3 / 8)
    enc, xhat = tk.roundtrip(x)
    assert sorted(np.abs(np.asarray(enc.values)).tolist(), reverse=True) == \
        [5.0, 3.0, 1.0]
    kept = np.asarray(xhat)
    assert np.count_nonzero(kept) == 3
    _, exact = codecs.TopK(frac=1.0).roundtrip(x)
    np.testing.assert_array_equal(np.asarray(exact), np.asarray(x))


def test_chain_codec_composes_topk_then_quantize():
    x = jax.random.normal(jax.random.PRNGKey(5), (512,))
    ch = codecs.Chain(sparse=codecs.TopK(frac=0.125),
                      quant=codecs.StochasticQuantizer(bits=8, chunk=64))
    enc, xhat = ch.roundtrip(x, jax.random.PRNGKey(6))
    nz = np.flatnonzero(np.asarray(xhat))
    assert len(nz) <= 64
    assert set(nz.tolist()) <= set(np.asarray(enc.indices).tolist())
    # chain wire cost: indices + quantized values, well under dense topk
    assert ch.nbytes(512) < codecs.TopK(frac=0.125).nbytes(512)


def test_quantizer_requires_prng_key():
    """Stochastic codecs must refuse key=None (reused noise breaks
    unbiasedness); deterministic codecs accept it."""
    with pytest.raises(ValueError, match="PRNG key"):
        codecs.StochasticQuantizer().encode(jnp.ones((8,)))
    codecs.TopK(frac=0.5).encode(jnp.ones((8,)))       # fine without a key


def test_quantize_kernel_device_prng_requires_seed():
    from repro.kernels.quantize import stochastic_quantize_pallas
    with pytest.raises(ValueError, match="seed"):
        stochastic_quantize_pallas(jnp.ones((8,)), 127, 8)


def test_make_codec_names_and_unknown():
    assert make_codec("none") is None and make_codec(None) is None
    assert isinstance(make_codec("int4"), codecs.StochasticQuantizer)
    assert make_codec("int4").bits == 4
    assert isinstance(make_codec("topk", topk_frac=0.2), codecs.TopK)
    with pytest.raises(ValueError):
        make_codec("gzip")


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------


def test_vector_nbytes_and_ratio():
    sq = codecs.StochasticQuantizer(bits=8, chunk=256)
    assert accounting.vector_nbytes(1000) == 4000
    assert accounting.vector_nbytes(1000, sq) == 4 * 4 + 1000
    assert accounting.compression_ratio(sq, 1000) > 3.5
    i4 = codecs.StochasticQuantizer(bits=4, chunk=256)
    assert accounting.vector_nbytes(1000, i4) == 4 * 4 + 500
    assert accounting.compression_ratio(i4, 1000) > 7.0


def test_sample_round_bytes_participation_and_constraints():
    sq = codecs.StochasticQuantizer(bits=8, chunk=256)
    full = accounting.sample_round_bytes(1000, 10, sq)
    part = accounting.sample_round_bytes(1000, 10, sq, participation=3)
    assert part["up"] * 10 == full["up"] * 3          # only S clients upload
    assert part["down"] == full["down"]               # broadcast stays dense
    cons = accounting.sample_round_bytes(1000, 10, sq, num_constraints=1)
    assert cons["up"] == 10 * (2 * sq.nbytes(1000) + 4)


def test_comm_ledger_accumulates():
    led = CommLedger()
    led.add({"up": 100, "down": 50, "total": 150}, n=3)
    led.add({"up": 10, "down": 5, "total": 15})
    s = led.summary()
    assert s["rounds"] == 4 and s["up"] == 310 and s["total"] == 465
    assert s["up_per_round"] == 77.5


def test_fed_reexports_float_counters():
    # fed.comm_load_per_round moved to accounting; same numbers as the seed
    r = fed.comm_load_per_round("sample", 100, num_clients=10)
    assert r == {"up": 1000, "down": 1000, "total": 2000}
    assert fed.comm_load_per_round is accounting.comm_load_per_round


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------


def test_ef_conservation_and_freeze():
    """x_hat + r' == x + r for any codec; inactive clients keep r unchanged."""
    tk = codecs.TopK(frac=0.1)
    x = jax.random.normal(jax.random.PRNGKey(7), (200,))
    r = jax.random.normal(jax.random.PRNGKey(8), (200,)) * 0.1
    _, xhat, r2 = error_feedback.ef_roundtrip(tk, x, r)
    np.testing.assert_allclose(np.asarray(xhat + r2), np.asarray(x + r),
                               atol=1e-6)
    _, _, frozen = error_feedback.ef_roundtrip(tk, x, r, active=jnp.zeros(()))
    np.testing.assert_array_equal(np.asarray(frozen), np.asarray(r))


def test_sample_round_participation_freezes_nonparticipant_residuals():
    z, y = _data(jax.random.PRNGKey(0))
    params = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, 4)
    codec = codecs.TopK(frac=0.05)
    dim = sum(l.size for l in jax.tree.leaves(params))
    ef0 = jax.random.normal(jax.random.PRNGKey(2), (4, dim)) * 0.1
    _, _, up = fed.sample_round(mlp.per_sample_loss, params, data,
                                jax.random.PRNGKey(3), 20, participation=2,
                                codec=codec, ef=ef0)
    pmask = np.asarray(up["participants"])
    changed = np.abs(np.asarray(up["ef"] - ef0)).max(axis=1)
    assert (changed[pmask == 0] == 0).all()
    assert (changed[pmask == 1] > 0).all()


def test_sample_round_wire_format_is_compressed():
    """Privacy/wire surface: with int8, what crosses the boundary is int8
    levels + fp32 per-chunk scales, and the byte count matches accounting."""
    z, y = _data(jax.random.PRNGKey(0))
    params = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, 4)
    sq = codecs.StochasticQuantizer(bits=8, chunk=64)
    _, _, up = fed.sample_round(mlp.per_sample_loss, params, data,
                                jax.random.PRNGKey(3), 20, codec=sq)
    assert up["encoded"].values.dtype == jnp.int8
    dim = sum(l.size for l in jax.tree.leaves(params))
    assert up["upload_nbytes"] == \
        accounting.sample_round_bytes(dim, 4, sq)["up"]


# ---------------------------------------------------------------------------
# codec state round-trips through the lax.scan carry (acceptance)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,atol,ef_atol", [
    # stochastic rounding floor(x/s + u) is discontinuous at integer tie
    # points, and XLA fuses the scan body differently from the per-round
    # jit: a one-ulp difference can flip one int8 level (= one EF-residual
    # quantization step, ~0.03 here) without any semantic divergence — the
    # trajectories first differ by float ulps only. The pin is therefore
    # loss/params at 5e-4 and EF within two quantization levels for int8,
    # and essentially-exact for the deterministic top-k codec.
    ("int8", 5e-4, 6e-2),
    ("topk", 1e-5, 1e-5),
])
def test_scan_matches_loop_with_codec(name, atol, ef_atol):
    """Compression on: the scan-compiled driver must produce the same
    trajectory as the per-round-dispatch loop — EF residuals and codec PRNG
    state round-trip through the scan carry/inputs (a wiring bug — dropped
    or zeroed residuals, wrong per-round keys — shows up at O(0.1))."""
    z, y = _data(jax.random.PRNGKey(0))
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, 4)
    fl = _fl()
    codec = make_codec(name, topk_frac=0.2)
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0, codec=codec)
    r_scan = algorithms.algorithm1(mlp.per_sample_loss, params0, data, fl,
                                   50, **kw)
    r_loop = algorithms.algorithm1(mlp.per_sample_loss, params0, data, fl,
                                   50, driver="loop", **kw)
    np.testing.assert_allclose(np.asarray(r_scan.history["round_loss_est"]),
                               np.asarray(r_loop.history["round_loss_est"]),
                               atol=atol)
    for a, b in zip(jax.tree.leaves(r_scan.params),
                    jax.tree.leaves(r_loop.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol)
    # EF residuals themselves must agree between the two drivers
    np.testing.assert_allclose(np.asarray(r_scan.final_state.ef),
                               np.asarray(r_loop.final_state.ef),
                               atol=ef_atol)
    assert float(r_scan.history["round_upload_bytes"][0]) == \
        accounting.sample_round_bytes(
            sum(l.size for l in jax.tree.leaves(params0)), 4, codec)["up"]


def test_identity_codec_matches_dense_path_exactly():
    """codec=Identity must reproduce the codec=None trajectory bit-for-bit —
    the wiring itself introduces no drift."""
    z, y = _data(jax.random.PRNGKey(3))
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_dirichlet(z, y, 5, jax.random.PRNGKey(4), alpha=0.4)
    fl = _fl()
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0, participation=2)
    dense = algorithms.algorithm1(mlp.per_sample_loss, params0, data, fl,
                                  30, **kw)
    ident = algorithms.algorithm1(mlp.per_sample_loss, params0, data, fl,
                                  30, codec=codecs.Identity(), **kw)
    for a, b in zip(jax.tree.leaves(dense.params),
                    jax.tree.leaves(ident.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_topk_ef_recovers_dense_trajectory_as_k_to_p():
    """Error feedback makes top-k consistent: at k = P the compressed
    trajectory equals the dense one exactly, and the k -> P loss gap shrinks."""
    z, y = _data(jax.random.PRNGKey(5), n=300)
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, 4)
    fl = _fl()
    kw = dict(key=jax.random.PRNGKey(2), eval_every=0)
    dense = algorithms.algorithm1(mlp.per_sample_loss, params0, data, fl,
                                  60, **kw)

    def final_gap(frac):
        r = algorithms.algorithm1(mlp.per_sample_loss, params0, data, fl, 60,
                                  codec=codecs.TopK(frac=frac), **kw)
        return float(jnp.abs(r.history["round_loss_est"][-1]
                             - dense.history["round_loss_est"][-1]))

    assert final_gap(1.0) < 1e-6                      # k = P: exact recovery
    assert final_gap(0.5) <= final_gap(0.02) + 1e-6   # gap shrinks with k


def test_constrained_feature_codec_runs_and_converges():
    z, y = _data(jax.random.PRNGKey(6), n=300)
    fdata = fed.partition_features(z, y, 3)
    blocks = jnp.stack([
        mlp.init(jax.random.fold_in(jax.random.PRNGKey(1), i),
                 fdata.feature_blocks.shape[-1], J, L)["w1"]
        for i in range(3)])
    params0 = {"w0": mlp.init(jax.random.PRNGKey(1), P, J, L)["w0"],
               "blocks": blocks}
    fl = _fl(batch_size=30)
    r = algorithms.algorithm3(mlp.per_sample_loss_from_h, mlp.client_h,
                              params0, fdata, fl, 60, jax.random.PRNGKey(2),
                              eval_every=0, codec=make_codec("int8"))
    losses = np.asarray(r.history["round_loss_est"])
    assert np.isfinite(losses).all()
    assert losses[-10:].mean() < losses[:10].mean()
    assert float(r.history["round_upload_bytes"][0]) > 0


def test_sample_sgd_identity_codec_matches_dense():
    """Delta compression with the identity codec reproduces plain weighted
    model averaging exactly (sum of weights is 1)."""
    z, y = _data(jax.random.PRNGKey(0))
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, 4)
    cfg = SGDConfig(lr_a=0.3, lr_alpha=0.3, local_batch=20, local_steps=2)
    dense = baselines.sample_sgd(mlp.per_sample_loss, params0, data, cfg, 20,
                                 jax.random.PRNGKey(2))
    ident = baselines.sample_sgd(mlp.per_sample_loss, params0, data, cfg, 20,
                                 jax.random.PRNGKey(2),
                                 codec=codecs.Identity())
    for a, b in zip(jax.tree.leaves(dense.params),
                    jax.tree.leaves(ident.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# headline acceptance: int8 on the quickstart workload
# ---------------------------------------------------------------------------


def test_int8_quickstart_within_2pct_at_3p5x_fewer_bytes():
    """Fig.-3 claim, measured: int8 stochastic quantization reaches within
    2% relative final loss of the uncompressed quickstart run while the
    accounting reports >= 3.5x fewer upload bytes."""
    key = jax.random.PRNGKey(0)
    (z, y, _), _ = classification_dataset(key, n=4000, num_features=784,
                                          num_classes=10, test_n=100,
                                          noise=4.0)
    params0 = mlp.init(jax.random.PRNGKey(1), 784, 64, 10)
    data = fed.partition_samples(z, y, num_clients=10)
    fl = FLConfig(num_clients=10, batch_size=100, a1=0.3, a2=0.3,
                  alpha_rho=0.1, alpha_gamma=0.6, tau=0.05, l2_lambda=1e-5)

    def eval_fn(params, state):
        return {"cost": float(mlp.mean_loss(params, z, y))}

    kw = dict(key=jax.random.PRNGKey(2), eval_fn=eval_fn, eval_every=100)
    dense = algorithms.algorithm1(mlp.per_sample_loss, params0, data, fl,
                                  100, **kw)
    codec = make_codec("int8")
    comp = algorithms.algorithm1(mlp.per_sample_loss, params0, data, fl,
                                 100, codec=codec, **kw)
    l_dense = float(dense.history["cost"][-1])
    l_comp = float(comp.history["cost"][-1])
    assert abs(l_comp - l_dense) / l_dense < 0.02
    bytes_dense = float(dense.history["round_upload_bytes"].sum())
    bytes_comp = float(comp.history["round_upload_bytes"].sum())
    assert bytes_dense / bytes_comp >= 3.5

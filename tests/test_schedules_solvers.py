"""Unit + property tests for stepsize schedules and convex-subproblem solvers."""

import pytest

pytest.importorskip("hypothesis")
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import schedules
from repro.core.solvers import (lemma1_nu, solve_constrained_multi,
                                solve_constrained_single, solve_unconstrained)
from repro.core.surrogate import (QuadSurrogate, init_surrogate, surrogate_grad,
                                  surrogate_value, tree_dot, tree_l2sq,
                                  update_surrogate)


def test_schedule_conditions():
    assert schedules.check_conditions(0.9, 0.5, 0.1, 0.6) == []
    # the paper's own empirical setting violates (6) strictly
    bad = schedules.check_conditions(0.9, 0.5, 0.1, 0.1)
    assert len(bad) == 2
    assert float(schedules.rho(1, 0.9, 0.1)) <= 1.0
    assert float(schedules.gamma(10**6, 0.5, 0.6)) < 1e-3


def test_unconstrained_solver_is_argmin():
    g = {"a": jnp.array([1.0, -2.0]), "b": jnp.array([[0.5]])}
    tau = 0.3
    w = solve_unconstrained(g, tau)
    # gradient of gᵀω + τ‖ω‖² at ω̄ must vanish
    grad = jax.tree.map(lambda gg, ww: gg + 2 * tau * ww, g, w)
    assert max(abs(float(jnp.max(jnp.abs(x)))) for x in jax.tree.leaves(grad)) < 1e-6


@settings(deadline=None, max_examples=40)
@given(st.integers(0, 2**31 - 1), st.floats(0.05, 2.0), st.floats(-3.0, 3.0),
       st.floats(0.05, 2.0))
def test_single_constraint_kkt(seed, tau, d1, tau0):
    """Property: the bisection solution satisfies the KKT conditions of
    Problem 5 (M=1) — primal feasibility w.r.t. slack, stationarity, and
    complementary slackness."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    g0 = jax.random.normal(k1, (8,))
    g1 = jax.random.normal(k2, (8,))
    c = 10.0
    cons = QuadSurrogate(d=jnp.float32(d1), g=g1)
    sol = solve_constrained_single(g0, tau0, cons, tau, c)
    w, nu, s = sol.omega_bar, float(sol.nu[0]), float(sol.slack[0])
    # stationarity: g0 + 2 τ0 ω + ν (g1 + 2 τ ω) = 0
    stat = g0 + 2 * tau0 * w + nu * (g1 + 2 * tau * w)
    assert float(jnp.max(jnp.abs(stat))) < 1e-2 * (1 + nu)
    f1 = d1 + float(g1 @ w) + tau * float(w @ w)
    # primal feasibility with slack
    assert f1 <= s + 1e-3
    # complementary slackness: s > 0 only if ν = c
    if s > 1e-5:
        assert abs(nu - c) < 1e-3


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 2**31 - 1), st.floats(-1.0, 1.0))
def test_multi_matches_single(seed, d1):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    g0 = jax.random.normal(k1, (6,))
    g1 = jax.random.normal(k2, (6,))
    tau = 0.4
    cons = QuadSurrogate(d=jnp.float32(d1), g=g1)
    s1 = solve_constrained_single(g0, tau, cons, tau, 5.0)
    sm = solve_constrained_multi(g0, tau, [cons], tau, 5.0, iters=3000)
    np.testing.assert_allclose(np.asarray(s1.omega_bar),
                               np.asarray(sm.omega_bar), atol=2e-2)


def test_lemma1_matches_bisection():
    """The paper's Lemma 1 closed form (g0 = 0, τ0 = 1) vs generic bisection."""
    key = jax.random.PRNGKey(3)
    g1 = jax.random.normal(key, (32,))
    for d1 in (-0.5, 0.0, 0.3, 5.0):
        tau, c = 0.2, 100.0
        cons = QuadSurrogate(d=jnp.float32(d1), g=g1)
        nu_l = float(lemma1_nu(tree_l2sq(g1), jnp.float32(d1), tau, c))
        sol = solve_constrained_single(jnp.zeros(32), 1.0, cons, tau, c)
        assert abs(nu_l - float(sol.nu[0])) < 1e-2 * (1 + nu_l), (d1, nu_l, sol.nu)


def test_surrogate_recursion_matches_closed_form():
    """F̄^t as stored (d, g) must equal the explicit weighted average of the
    per-round quadratic surrogates (eq. (3) unrolled)."""
    key = jax.random.PRNGKey(0)
    params = jax.random.normal(key, (5,))
    tau = 0.2
    s = init_surrogate(params)
    omegas, grads, vals, rhos = [], [], [], []
    w = params
    for t in range(1, 6):
        kt = jax.random.fold_in(key, t)
        g = jax.random.normal(kt, (5,))
        v = float(jax.random.normal(jax.random.fold_in(kt, 1), ()))
        rho = 1.0 if t == 1 else 0.9 / t**0.1
        s = update_surrogate(s, rho, w, g, v, tau)
        omegas.append(w); grads.append(g); vals.append(v); rhos.append(rho)
        w = w - 0.1 * jax.random.normal(jax.random.fold_in(kt, 2), (5,))

    probe = jax.random.normal(jax.random.fold_in(key, 99), (5,))
    # explicit: sum_t c_t * fbar_t(probe), c_t = rho_t * prod_{r>t} (1-rho_r)
    expect = 0.0
    for t in range(5):
        coef = rhos[t] * np.prod([1 - r for r in rhos[t + 1:]])
        fbar = vals[t] + float(grads[t] @ (probe - omegas[t])) \
            + tau * float((probe - omegas[t]) @ (probe - omegas[t]))
        expect += coef * fbar
    got = float(surrogate_value(s, probe, tau))
    np.testing.assert_allclose(got, expect, rtol=1e-4)

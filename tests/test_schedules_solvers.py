"""Unit + property tests for stepsize schedules and convex-subproblem solvers."""

import pytest

pytest.importorskip("hypothesis")
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import schedules
from repro.core.solvers import (kkt_best_nu, kkt_residuals, lemma1_nu,
                                solve_constrained_multi,
                                solve_constrained_single, solve_unconstrained)
from repro.core.surrogate import (QuadSurrogate, init_surrogate, surrogate_grad,
                                  surrogate_value, tree_dot, tree_l2sq,
                                  update_surrogate)


def test_schedule_conditions():
    assert schedules.check_conditions(0.9, 0.5, 0.1, 0.6) == []
    # the paper's own empirical setting violates (6) strictly
    bad = schedules.check_conditions(0.9, 0.5, 0.1, 0.1)
    assert len(bad) == 2
    assert float(schedules.rho(1, 0.9, 0.1)) <= 1.0
    assert float(schedules.gamma(10**6, 0.5, 0.6)) < 1e-3


def test_unconstrained_solver_is_argmin():
    g = {"a": jnp.array([1.0, -2.0]), "b": jnp.array([[0.5]])}
    tau = 0.3
    w = solve_unconstrained(g, tau)
    # gradient of gᵀω + τ‖ω‖² at ω̄ must vanish
    grad = jax.tree.map(lambda gg, ww: gg + 2 * tau * ww, g, w)
    assert max(abs(float(jnp.max(jnp.abs(x)))) for x in jax.tree.leaves(grad)) < 1e-6


@settings(deadline=None, max_examples=40)
@given(st.integers(0, 2**31 - 1), st.floats(0.05, 2.0), st.floats(-3.0, 3.0),
       st.floats(0.05, 2.0))
def test_single_constraint_kkt(seed, tau, d1, tau0):
    """Property: the bisection solution satisfies the KKT conditions of
    Problem 5 (M=1) — primal feasibility w.r.t. slack, stationarity, and
    complementary slackness."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    g0 = jax.random.normal(k1, (8,))
    g1 = jax.random.normal(k2, (8,))
    c = 10.0
    cons = QuadSurrogate(d=jnp.float32(d1), g=g1)
    sol = solve_constrained_single(g0, tau0, cons, tau, c)
    w, nu, s = sol.omega_bar, float(sol.nu[0]), float(sol.slack[0])
    # stationarity: g0 + 2 τ0 ω + ν (g1 + 2 τ ω) = 0
    stat = g0 + 2 * tau0 * w + nu * (g1 + 2 * tau * w)
    assert float(jnp.max(jnp.abs(stat))) < 1e-2 * (1 + nu)
    f1 = d1 + float(g1 @ w) + tau * float(w @ w)
    # primal feasibility with slack
    assert f1 <= s + 1e-3
    # complementary slackness: s > 0 only if ν = c
    if s > 1e-5:
        assert abs(nu - c) < 1e-3


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 2**31 - 1), st.floats(-1.0, 1.0))
def test_multi_matches_single(seed, d1):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    g0 = jax.random.normal(k1, (6,))
    g1 = jax.random.normal(k2, (6,))
    tau = 0.4
    cons = QuadSurrogate(d=jnp.float32(d1), g=g1)
    s1 = solve_constrained_single(g0, tau, cons, tau, 5.0)
    sm = solve_constrained_multi(g0, tau, [cons], tau, 5.0, iters=3000)
    np.testing.assert_allclose(np.asarray(s1.omega_bar),
                               np.asarray(sm.omega_bar), atol=2e-2)


def test_lemma1_matches_bisection():
    """The paper's Lemma 1 closed form (g0 = 0, τ0 = 1) vs generic bisection."""
    key = jax.random.PRNGKey(3)
    g1 = jax.random.normal(key, (32,))
    for d1 in (-0.5, 0.0, 0.3, 5.0):
        tau, c = 0.2, 100.0
        cons = QuadSurrogate(d=jnp.float32(d1), g=g1)
        nu_l = float(lemma1_nu(tree_l2sq(g1), jnp.float32(d1), tau, c))
        sol = solve_constrained_single(jnp.zeros(32), 1.0, cons, tau, c)
        assert abs(nu_l - float(sol.nu[0])) < 1e-2 * (1 + nu_l), (d1, nu_l, sol.nu)


@settings(deadline=None, max_examples=40)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4), st.floats(0.05, 1.0),
       st.floats(0.05, 1.0))
def test_multi_constraint_kkt_randomized_active_sets(seed, m, tau, tau0):
    """Property: solve_constrained_multi's dual ascent lands on a point
    satisfying the KKT system of Problem 5 for ANY mix of active and
    inactive constraints — constraint offsets d_m ∈ [-2, 2] randomize which
    constraints bind at the solution (d_m << 0 inactive, d_m >> 0 active or
    slack-saturated). Checked with the same kkt_residuals yardstick that
    benchmarks/feature_bench.py scores Algorithm 4 with."""
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, m + 2)
    g0 = jax.random.normal(keys[0], (8,))
    gs = [jax.random.normal(k, (8,)) for k in keys[1:m + 1]]
    ds = jax.random.uniform(keys[m + 1], (m,), minval=-2.0, maxval=2.0)
    c = 10.0
    cons = [QuadSurrogate(d=ds[j], g=gs[j]) for j in range(m)]
    sol = solve_constrained_multi(g0, tau0, cons, tau, c, iters=3000)
    w = sol.omega_bar
    nu = np.asarray(sol.nu)
    slack = np.asarray(sol.slack)
    fvals = np.asarray([float(ds[j] + gs[j] @ w + tau * (w @ w))
                        for j in range(m)])
    nu_scale = 1.0 + float(nu.sum())

    # stationarity via the shared residual helper: ∇f0 + Σ ν_m ∇F_m ≈ 0
    # (each surrogate's curvature contributes 2τω; f0's contributes 2τ0ω)
    obj_grad = g0 + 2 * tau0 * w
    cons_grads = [gs[j] + 2 * tau * w for j in range(m)]
    res = kkt_residuals(obj_grad, cons_grads, fvals - slack, nu)
    assert float(res["stationarity"]) < 2e-2 * nu_scale
    # primal feasibility w.r.t. the solved slack
    assert float(res["violation"]) < 1e-3
    # dual feasibility: 0 <= nu_m <= c
    assert (nu >= -1e-6).all() and (nu <= c + 1e-6).all()
    # complementary slackness, both directions
    for j in range(m):
        if slack[j] > 1e-4:               # paid slack => multiplier at cap
            assert abs(nu[j] - c) < 1e-2
        if fvals[j] < slack[j] - 1e-2:    # strictly inactive => nu ~ 0
            assert nu[j] < 1e-2 * nu_scale


def test_kkt_residuals_and_best_nu_closed_form():
    """kkt_residuals on a hand-built KKT point is ~0; kkt_best_nu recovers
    the stationarity-minimizing multiplier and clips at 0."""
    g = jnp.array([1.0, -2.0, 0.5])
    # point where obj_grad = -2 * cons_grad: best nu is exactly 2
    r = kkt_residuals(-2.0 * g, [g], jnp.array([0.0]), jnp.array([2.0]))
    assert float(r["stationarity"]) < 1e-6
    assert float(r["violation"]) == 0.0
    assert float(r["comp_slack"]) == 0.0
    np.testing.assert_allclose(float(kkt_best_nu(-2.0 * g, g)), 2.0,
                               rtol=1e-6)
    # anti-aligned gradients would need nu < 0 — clipped to the valid cone
    assert float(kkt_best_nu(3.0 * g, g)) == 0.0
    # violation and comp_slack pick up positive constraint values
    r = kkt_residuals(jnp.zeros(3), [g], jnp.array([0.5]), jnp.array([4.0]))
    assert float(r["violation"]) == 0.5
    np.testing.assert_allclose(float(r["comp_slack"]), 2.0, rtol=1e-6)


def test_surrogate_recursion_matches_closed_form():
    """F̄^t as stored (d, g) must equal the explicit weighted average of the
    per-round quadratic surrogates (eq. (3) unrolled)."""
    key = jax.random.PRNGKey(0)
    params = jax.random.normal(key, (5,))
    tau = 0.2
    s = init_surrogate(params)
    omegas, grads, vals, rhos = [], [], [], []
    w = params
    for t in range(1, 6):
        kt = jax.random.fold_in(key, t)
        g = jax.random.normal(kt, (5,))
        v = float(jax.random.normal(jax.random.fold_in(kt, 1), ()))
        rho = 1.0 if t == 1 else 0.9 / t**0.1
        s = update_surrogate(s, rho, w, g, v, tau)
        omegas.append(w); grads.append(g); vals.append(v); rhos.append(rho)
        w = w - 0.1 * jax.random.normal(jax.random.fold_in(kt, 2), (5,))

    probe = jax.random.normal(jax.random.fold_in(key, 99), (5,))
    # explicit: sum_t c_t * fbar_t(probe), c_t = rho_t * prod_{r>t} (1-rho_r)
    expect = 0.0
    for t in range(5):
        coef = rhos[t] * np.prod([1 - r for r in rhos[t + 1:]])
        fbar = vals[t] + float(grads[t] @ (probe - omegas[t])) \
            + tau * float((probe - omegas[t]) @ (probe - omegas[t]))
        expect += coef * fbar
    got = float(surrogate_value(s, probe, tau))
    np.testing.assert_allclose(got, expect, rtol=1e-4)

"""Federated protocol invariants: aggregation correctness, client-count
independence, the privacy surface (only B-summed statistics leave a client),
and communication-load accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fed
from repro.models import mlp

P, J, L = 12, 6, 3


def _data(key, n=240):
    z = jax.random.normal(key, (n, P))
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, L)
    return z, jax.nn.one_hot(lab, L)


def psl(p, z, y):
    return mlp.per_sample_loss(p, z, y)


def test_weighted_aggregation_equals_global_batch_gradient():
    """Σ_i N_i/(BN) q_i with equal N_i must equal the plain mini-batch mean
    gradient computed over the union of the selected samples."""
    key = jax.random.PRNGKey(0)
    z, y = _data(key)
    params = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, 4)
    B = 10
    grad_est, val_est, up = fed.sample_round(psl, params, data, key, B,
                                             with_value=True)
    # recompute by hand from the same PRNG-selected indices
    idx = fed.sample_batches(data, key, B)
    zs = jnp.concatenate([data.features[i][idx[i]] for i in range(4)])
    ys = jnp.concatenate([data.labels[i][idx[i]] for i in range(4)])
    ref = jax.grad(lambda p: jnp.mean(mlp.per_sample_loss(p, zs, ys)))(params)
    for a, b in zip(jax.tree.leaves(grad_est), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-6)
    np.testing.assert_allclose(
        float(val_est), float(jnp.mean(mlp.per_sample_loss(params, zs, ys))),
        rtol=2e-4)


def test_unequal_client_sizes_weighting():
    """Ragged N_i: weights must be N_i/(BN), not 1/I."""
    key = jax.random.PRNGKey(2)
    z, y = _data(key, n=100)
    params = mlp.init(jax.random.PRNGKey(1), P, J, L)
    counts = jnp.array([70, 30], jnp.int32)
    features = jnp.zeros((2, 70, P)).at[0].set(z[:70]).at[1, :30].set(z[70:])
    labels = jnp.zeros((2, 70, L)).at[0].set(y[:70]).at[1, :30].set(y[70:])
    data = fed.SampleFedData(features, labels, counts)
    B = 5
    grad_est, _, _ = fed.sample_round(psl, params, data, key, B)
    idx = fed.sample_batches(data, key, B)
    g0 = jax.grad(lambda p: jnp.sum(mlp.per_sample_loss(
        p, features[0][idx[0]], labels[0][idx[0]])))(params)
    g1 = jax.grad(lambda p: jnp.sum(mlp.per_sample_loss(
        p, features[1][idx[1]], labels[1][idx[1]])))(params)
    ref = jax.tree.map(lambda a, b: (70 * a / B + 30 * b / B) / 100.0, g0, g1)
    for a, b in zip(jax.tree.leaves(grad_est), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-6)


def test_privacy_surface_only_batch_sums():
    """The uploads structure contains exactly the q-statistics of the paper:
    B-summed gradients (and values), nothing per-sample."""
    key = jax.random.PRNGKey(0)
    z, y = _data(key)
    params = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, 4)
    B = 10
    _, _, up = fed.sample_round(psl, params, data, key, B, with_value=True)
    # every upload leaf is (I, ...param-shaped) — no B-sized leading dims
    for leaf in jax.tree.leaves(up["q_grad_sums"]):
        assert leaf.shape[0] == 4
        assert B not in leaf.shape[1:], "per-sample data crossed the boundary"
    assert up["q_value_sums"].shape == (4,)


def test_feature_round_equals_full_gradient():
    """Alg-3 info collection (h-exchange + chain rule) must reproduce the
    full autodiff gradient of the composed loss."""
    key = jax.random.PRNGKey(4)
    z, y = _data(key)
    data = fed.partition_features(z, y, 3)
    pi = data.feature_blocks.shape[-1]
    w1 = jax.random.normal(key, (3, J, pi)) * 0.3
    w0 = jax.random.normal(jax.random.fold_in(key, 1), (L, J)) * 0.3
    params = {"w0": w0, "blocks": w1}
    B = 16
    grad_est, val, up = fed.feature_round(
        params, data, key, B, mlp.per_sample_loss_from_h, mlp.client_h)

    idx = jax.random.randint(key, (B,), 0, data.total)
    zb = jnp.take(data.feature_blocks, idx, axis=1)
    yb = jnp.take(data.labels, idx, axis=0)

    def full_loss(p):
        hsum = sum(mlp.client_h(p["blocks"][i], zb[i]) for i in range(3))
        return jnp.mean(mlp.per_sample_loss_from_h(p["w0"], hsum, yb))

    ref = jax.grad(full_loss)(params)
    for a, b in zip(jax.tree.leaves(grad_est), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-6)
    # privacy surface: h-exchange is (I, B, J) — feature blocks never leave
    assert up["h_exchange"].shape == (3, B, J)


def test_comm_load_accounting():
    d = 1000
    r = fed.comm_load_per_round("sample", d, num_clients=10)
    assert r["up"] == 10 * d and r["down"] == 10 * d
    r = fed.comm_load_per_round("sample", d, num_clients=10, num_constraints=1)
    assert r["up"] == 10 * (d + 1 + d)
    r = fed.comm_load_per_round("feature", d, d_blocks=[90] * 10,
                                batch_size=8, h_dim=6, num_clients=10)
    assert r["h_exchange"] == 8 * 6 * 10 * 9

"""End-to-end behaviour of the paper's system (Algorithms 1-4 + baselines) on
a synthetic classification task of the paper's shape (scaled down for CI).

Validated claims (relative orderings, §VI):
  - Alg 1 / Alg 3 decrease the training cost and beat FedSGD per round
  - Alg 2 / Alg 4 drive the slack to ~0 and satisfy F(ω) <= U (+tolerance)
    while minimizing ‖ω‖²  (Theorems 2/4 behaviour)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import algorithms, baselines, fed
from repro.core.baselines import SGDConfig
from repro.data.synthetic import classification_dataset
from repro.models import mlp

P, J, L, N, I = 32, 16, 5, 3000, 6
ROUNDS = 150


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    (z, y, lab), (zt, yt, labt) = classification_dataset(
        key, n=N, num_features=P, num_classes=L, test_n=500)
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, I)
    fdata = fed.partition_features(z, y, 4)
    return dict(z=z, y=y, zt=zt, labt=labt, params0=params0, data=data,
                fdata=fdata)


def psl(p, z, y):
    return mlp.per_sample_loss(p, z, y)


def _eval(problem):
    def eval_fn(params, state):
        return {"loss": float(mlp.mean_loss(params, problem["z"][:1000],
                                            problem["y"][:1000]))}
    return eval_fn


def test_algorithm1_converges_and_beats_fedsgd(problem):
    fl = FLConfig(batch_size=50, a1=0.9, a2=0.5, alpha_rho=0.1,
                  alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5)
    r1 = algorithms.algorithm1(psl, problem["params0"], problem["data"], fl,
                               ROUNDS, jax.random.PRNGKey(2),
                               eval_fn=_eval(problem), eval_every=ROUNDS // 3)
    sgd = baselines.sample_sgd(psl, problem["params0"], problem["data"],
                               SGDConfig(lr_a=0.3, lr_alpha=0.3, local_steps=1,
                                         local_batch=50),
                               ROUNDS, jax.random.PRNGKey(2),
                               eval_fn=_eval(problem), eval_every=ROUNDS // 3)
    l1 = np.asarray(r1.history["loss"])
    ls = np.asarray(sgd.history["loss"])
    assert l1[-1] < l1[0], "Alg 1 did not decrease the training cost"
    assert l1[-1] < ls[-1], f"SSCA {l1[-1]} not faster than FedSGD {ls[-1]}"
    assert np.isfinite(l1).all()


def test_algorithm2_constrained_feasibility(problem):
    u = 1.3
    fl = FLConfig(batch_size=50, a1=0.9, a2=0.5, alpha_rho=0.1,
                  alpha_gamma=0.6, tau=0.2, constrained=True, cost_limit=u,
                  penalty_c=1e4)
    r2 = algorithms.algorithm2(psl, problem["params0"], problem["data"], fl,
                               400, jax.random.PRNGKey(3),
                               eval_fn=lambda p, s: {
                                   "loss": float(mlp.mean_loss(p, problem["z"][:1000],
                                                               problem["y"][:1000])),
                                   "l2": float(mlp.l2_sq(p)),
                                   "slack": float(s.slack)},
                               eval_every=100)
    loss = np.asarray(r2.history["loss"])
    slack = np.asarray(r2.history["slack"])
    assert slack[-1] < 1e-3, f"slack did not vanish: {slack}"
    assert loss[-1] <= u * 1.15, f"constraint violated: F={loss[-1]} > U={u}"
    # the minimum-norm solution should sit near the constraint boundary
    assert loss[-1] >= u * 0.5


def test_algorithm3_feature_based(problem):
    fdata = problem["fdata"]
    pi = fdata.feature_blocks.shape[-1]
    w1 = problem["params0"]["w1"]
    pad = 4 * pi - P
    w1p = jnp.pad(w1, ((0, 0), (0, pad)))
    fparams0 = {"w0": problem["params0"]["w0"],
                "blocks": w1p.reshape(J, 4, pi).transpose(1, 0, 2)}
    fl = FLConfig(batch_size=64, a1=0.9, a2=0.5, alpha_rho=0.1,
                  alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5, mode="feature")

    def eval_fn(p, s):
        hsum = sum(mlp.client_h(p["blocks"][i], fdata.feature_blocks[i][:800])
                   for i in range(4))
        return {"loss": float(jnp.mean(mlp.per_sample_loss_from_h(
            p["w0"], hsum, problem["y"][:800])))}

    r3 = algorithms.algorithm3(mlp.per_sample_loss_from_h, mlp.client_h,
                               fparams0, fdata, fl, ROUNDS,
                               jax.random.PRNGKey(4), eval_fn=eval_fn,
                               eval_every=ROUNDS // 3)
    l3 = np.asarray(r3.history["loss"])
    assert l3[-1] < l3[0] and np.isfinite(l3).all()


def test_algorithm4_constrained_feature_based(problem):
    fdata = problem["fdata"]
    pi = fdata.feature_blocks.shape[-1]
    w1p = jnp.pad(problem["params0"]["w1"], ((0, 0), (0, 4 * pi - P)))
    fparams0 = {"w0": problem["params0"]["w0"],
                "blocks": w1p.reshape(J, 4, pi).transpose(1, 0, 2)}
    u = 1.4
    fl = FLConfig(batch_size=64, a1=0.9, a2=0.5, alpha_rho=0.1,
                  alpha_gamma=0.6, tau=0.2, constrained=True, cost_limit=u,
                  penalty_c=1e4, mode="feature")
    r4 = algorithms.algorithm4(mlp.per_sample_loss_from_h, mlp.client_h,
                               fparams0, fdata, fl, 400, jax.random.PRNGKey(5),
                               eval_fn=lambda p, s: {"slack": float(s.slack)},
                               eval_every=100)
    assert float(np.asarray(r4.history["slack"])[-1]) < 1e-3


def test_general_constrained_algorithm2(problem):
    """Full Algorithm 2 (sampled objective AND constraint, bisection solver)."""
    fl = FLConfig(batch_size=50, tau=0.2, cost_limit=1.5, penalty_c=1e4,
                  alpha_gamma=0.6)
    r = algorithms.algorithm2_general(psl, psl, problem["params0"],
                                      problem["data"], fl, 150,
                                      jax.random.PRNGKey(6),
                                      eval_fn=lambda p, s: {
                                          "loss": float(mlp.mean_loss(
                                              p, problem["z"][:500],
                                              problem["y"][:500])),
                                          "slack": float(s.slack)},
                                      eval_every=50)
    loss = np.asarray(r.history["loss"])
    assert np.isfinite(loss).all()
    assert loss[-1] < loss[0] * 1.05

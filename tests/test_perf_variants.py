"""§Perf knobs must be semantics-preserving: chunked (flash-style) attention,
sequence-sharded activations, and expert2d MoE sharding all compute the same
function as the baseline."""

import pytest

pytest.importorskip("hypothesis")
import dataclasses

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.configs.registry import ARCHS
from repro.models import get_model
from repro.models import layers as L


@settings(deadline=None, max_examples=15)
@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 4]),
       st.sampled_from([16, 32, 100]), st.booleans(), st.sampled_from([0, 24]))
def test_chunked_attention_matches_dot(seed, rep, block, causal, window):
    key = jax.random.PRNGKey(seed)
    b, h, s, hd = 2, 4, 48, 16
    kv = h // rep
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd))
    pos = jnp.arange(s, dtype=jnp.int32)[None, :]
    mask = L.make_attention_mask(pos, pos, causal=causal, window=window)
    want = L.dot_attention(q, k, v, mask, kv_heads_repeat=rep)
    if rep > 1:
        kf = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, rep, hd)).reshape(b, s, h, hd)
        vf = jnp.broadcast_to(v[:, :, :, None, :], (b, s, kv, rep, hd)).reshape(b, s, h, hd)
    else:
        kf, vf = k, v
    got = L.chunked_attention(q, kf, vf, pos, pos, causal=causal,
                              window=window, block=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_chunked_attention_prefix_lm():
    key = jax.random.PRNGKey(9)
    b, h, s, hd, pfx = 1, 2, 40, 8, 12
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd))
    pos = jnp.arange(s, dtype=jnp.int32)[None, :]
    mask = L.make_attention_mask(pos, pos, causal=True, prefix_len=pfx)
    want = L.dot_attention(q, k, v, mask, kv_heads_repeat=1)
    got = L.chunked_attention(q, k, v, pos, pos, causal=True,
                              prefix_len=pfx, block=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("knob", [
    {"attention_impl": "chunked", "attention_block": 16},
    {"seq_shard_activations": True},     # no-op on 1 device, must still run
])
def test_dense_variant_loss_equal(knob):
    c0 = ARCHS["glm4-9b"].smoke()
    c1 = dataclasses.replace(c0, **knob)
    m = get_model(c0)
    key = jax.random.PRNGKey(0)
    params = m.init(key, c0)
    batch = {"tokens": jax.random.randint(key, (2, 48), 0, c0.vocab_size),
             "targets": jnp.ones((2, 48), jnp.int32)}
    l0 = float(m.loss_fn(params, batch, c0))
    l1 = float(m.loss_fn(params, batch, c1))
    assert abs(l0 - l1) < 1e-4, (l0, l1)


def test_moe_expert2d_loss_equal():
    c0 = ARCHS["qwen3-moe-30b-a3b"].smoke()
    c1 = dataclasses.replace(c0, moe_sharding="expert2d")
    m = get_model(c0)
    key = jax.random.PRNGKey(0)
    params = m.init(key, c0)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, c0.vocab_size),
             "targets": jnp.ones((2, 32), jnp.int32)}
    assert abs(float(m.loss_fn(params, batch, c0))
               - float(m.loss_fn(params, batch, c1))) < 1e-5

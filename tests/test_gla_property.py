"""Property tests for the chunked gated-linear-attention core (the SSD dual
form used by mamba2/mLSTM): the blocked algorithm must equal the naive
step-by-step recurrence for any chunk size, and prefill states must continue
the recurrence exactly."""

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.models.ssm import chunked_gla, gla_decode_step


def naive_gla(q, k, v, log_a):
    """Reference: H_t = a_t H_{t-1} + k_t v_tᵀ; y_t = q_t H_t."""
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    H = np.zeros((b, h, dk, dv), np.float64)
    ys = []
    for t in range(s):
        a = np.exp(np.asarray(log_a[..., t], np.float64))[..., None, None]
        H = a * H + np.einsum("bhd,bhv->bhdv",
                              np.asarray(k[..., t, :], np.float64),
                              np.asarray(v[..., t, :], np.float64))
        ys.append(np.einsum("bhd,bhdv->bhv", np.asarray(q[..., t, :], np.float64), H))
    return np.stack(ys, axis=2), H


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 2**31 - 1), st.integers(1, 40), st.sampled_from([1, 3, 8, 64]))
def test_chunked_equals_naive(seed, s, chunk):
    key = jax.random.PRNGKey(seed)
    b, h, dk, dv = 1, 2, 3, 4
    q = jax.random.normal(key, (b, h, s, dk))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, h, s, dk))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, h, s, dv))
    log_a = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (b, h, s))) * 0.5
    y, final = chunked_gla(q, k, v, log_a, chunk)
    y_ref, h_ref = naive_gla(q, k, v, log_a)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), h_ref, rtol=1e-4, atol=1e-4)


@settings(deadline=None, max_examples=15)
@given(st.integers(0, 2**31 - 1), st.integers(2, 20))
def test_prefill_state_continues_recurrence(seed, s):
    """chunked_gla's final state + one gla_decode_step == chunked over s+1."""
    key = jax.random.PRNGKey(seed)
    b, h, dk, dv = 1, 2, 3, 4
    q = jax.random.normal(key, (b, h, s + 1, dk))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, h, s + 1, dk))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, h, s + 1, dv))
    log_a = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (b, h, s + 1)))
    y_full, _ = chunked_gla(q, k, v, log_a, chunk=8)
    _, state = chunked_gla(q[:, :, :s], k[:, :, :s], v[:, :, :s],
                           log_a[..., :s], chunk=8)
    y_dec, _ = gla_decode_step(state, q[:, :, s], k[:, :, s], v[:, :, s],
                               log_a[..., s])
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, :, s]),
                               rtol=1e-4, atol=1e-4)


def test_noniid_clients_still_converge():
    """Beyond-paper robustness: label-sorted (non-IID) client partitions.
    The aggregate ĝ is still an unbiased gradient estimate (client weights
    N_i/N), so Algorithm 1 must still decrease the cost."""
    from repro.configs.base import FLConfig
    from repro.core import algorithms, fed
    from repro.data.synthetic import classification_dataset
    from repro.models import mlp

    key = jax.random.PRNGKey(0)
    (z, y, lab), _ = classification_dataset(key, n=2000, num_features=24,
                                            num_classes=4, test_n=10)
    order = jnp.argsort(lab)                      # sort by label -> non-IID shards
    data = fed.partition_samples(z[order], y[order], 4)
    params0 = mlp.init(jax.random.PRNGKey(1), 24, 12, 4)
    fl = FLConfig(batch_size=32, a1=0.9, a2=0.5, alpha_rho=0.1,
                  alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5)
    res = algorithms.algorithm1(
        lambda p, zz, yy: mlp.per_sample_loss(p, zz, yy), params0, data, fl,
        rounds=150, key=jax.random.PRNGKey(2),
        eval_fn=lambda p, s: {"loss": float(mlp.mean_loss(p, z, y))},
        eval_every=50)
    losses = np.asarray(res.history["loss"])
    assert losses[-1] < losses[0] * 0.8 and np.isfinite(losses).all()

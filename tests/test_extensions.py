"""Tests for the beyond-paper extensions: local SSCA updates, DP uploads,
and the shard_map vertical-FL realization (subprocess: needs >1 device)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import algorithms, fed
from repro.core.local_updates import algorithm1_local
from repro.core.privacy import DPConfig, noise_multiplier
from repro.data.synthetic import classification_dataset
from repro.models import mlp

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _problem():
    key = jax.random.PRNGKey(0)
    (z, y, _), _ = classification_dataset(key, n=2000, num_features=24,
                                          num_classes=4, test_n=10)
    params0 = mlp.init(jax.random.PRNGKey(1), 24, 12, 4)
    data = fed.partition_samples(z, y, 4)
    return z, y, params0, data


def psl(p, z, y):
    return mlp.per_sample_loss(p, z, y)


def test_local_updates_e1_equals_algorithm1():
    """E=1 must recover Algorithm 1 exactly (same PRNG -> same iterates)."""
    z, y, params0, data = _problem()
    fl = FLConfig(batch_size=32, a1=0.9, a2=0.5, alpha_rho=0.1,
                  alpha_gamma=0.6, tau=0.2, l2_lambda=1e-4)
    # NOTE: algorithm1 draws per-client batches via fed.sample_batches(key);
    # algorithm1_local folds (key_i, step). Iterates can't match bit-for-bit
    # across different batch draws, so compare on full-batch mode instead:
    big = FLConfig(batch_size=data.features.shape[1], a1=0.9, a2=0.5,
                   alpha_rho=0.1, alpha_gamma=0.6, tau=0.2, l2_lambda=1e-4)
    # full batch -> both draw (with replacement) from the same pool; use E=1
    r_loc = algorithm1_local(psl, params0, data, big, 30, jax.random.PRNGKey(2),
                             local_steps=1,
                             eval_fn=lambda p, s: {"loss": float(
                                 mlp.mean_loss(p, z, y))}, eval_every=30)
    r_ref = algorithms.algorithm1(psl, params0, data, big, 30,
                                  jax.random.PRNGKey(2),
                                  eval_fn=lambda p, s: {"loss": float(
                                      mlp.mean_loss(p, z, y))}, eval_every=30)
    # same stepsize schedule + unbiased full-pool sampling: trajectories agree
    assert abs(float(r_loc.history["loss"][-1])
               - float(r_ref.history["loss"][-1])) < 0.08


def test_local_updates_reduce_rounds():
    """E=4 local SSCA steps reach a target cost in fewer rounds than E=1
    (the paper's named future direction — communication saving)."""
    z, y, params0, data = _problem()
    fl = FLConfig(batch_size=32, a1=0.9, a2=0.5, alpha_rho=0.1,
                  alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5)
    ev = lambda p, s: {"loss": float(mlp.mean_loss(p, z, y))}
    r1 = algorithm1_local(psl, params0, data, fl, 120, jax.random.PRNGKey(3),
                          local_steps=1, eval_fn=ev, eval_every=30)
    r4 = algorithm1_local(psl, params0, data, fl, 120, jax.random.PRNGKey(3),
                          local_steps=4, eval_fn=ev, eval_every=30)
    l1 = np.asarray(r1.history["loss"])
    l4 = np.asarray(r4.history["loss"])
    assert l4[-1] < l1[-1], (l1, l4)


def test_dp_round_unbiased_and_noisy():
    z, y, params0, data = _problem()
    dp = DPConfig(clip_norm=50.0, epsilon=8.0, delta=1e-5)  # loose clip
    key = jax.random.PRNGKey(4)
    # unbiasedness: avg of noised rounds ~ avg of clean rounds (same batches)
    acc_dp, acc_clean = None, None
    n_avg = 60
    for i in range(n_avg):
        k = jax.random.fold_in(key, i)
        g_dp, _, _ = fed.sample_round(psl, params0, data, k, 32, dp=dp)
        g_cl, _, _ = fed.sample_round(psl, params0, data, k, 32)
        acc_dp = g_dp if acc_dp is None else jax.tree.map(jnp.add, acc_dp, g_dp)
        acc_clean = g_cl if acc_clean is None else jax.tree.map(jnp.add, acc_clean, g_cl)
    acc_dp = jax.tree.map(lambda a: a / n_avg, acc_dp)
    acc_clean = jax.tree.map(lambda a: a / n_avg, acc_clean)
    sigma = noise_multiplier(dp) * dp.clip_norm
    for a, b in zip(jax.tree.leaves(acc_dp), jax.tree.leaves(acc_clean)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=6 * sigma / np.sqrt(n_avg) + 5e-2)
    # a single noised upload differs from the clean one (privacy is "on")
    k0 = jax.random.fold_in(key, 0)
    g1, _, _ = fed.sample_round(psl, params0, data, k0, 32, dp=dp)
    g_cl, _, _ = fed.sample_round(psl, params0, data, k0, 32)
    diff = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g_cl)))
    assert diff > 1e-3


def test_feature_dist_shard_map_subprocess():
    """Vertical FL on a 4-device 'model' mesh via the modern topology API
    (the FLT004-deprecated feature_dist shims are no longer exercised):
    sharded feature_round grads == local reference; algorithm3 converges."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import FLConfig
        from repro.core import algorithms, fed
        from repro.core.topology import ShardedTopology
        from repro.data.synthetic import classification_dataset
        from repro.models import mlp

        mesh = jax.make_mesh((4,), ("model",))
        topo = ShardedTopology(mesh, axes=("model",))
        key = jax.random.PRNGKey(0)
        (z, y, _), _ = classification_dataset(key, n=800, num_features=24,
                                              num_classes=4, test_n=10)
        fdata = fed.partition_features(z, y, 4)
        pi = fdata.feature_blocks.shape[-1]
        w0 = jax.random.normal(key, (4, 12)) * 0.3
        blocks = jax.random.normal(jax.random.fold_in(key, 1), (4, 12, pi)) * 0.3
        params = {"w0": w0, "blocks": blocks}

        # one round: sharded psum h-exchange == local reference engine
        rk = jax.random.PRNGKey(7)
        g_sh, v_sh, _ = fed.feature_round(
            params, fdata, rk, 32, mlp.per_sample_loss_from_h, mlp.client_h,
            topology=topo)
        g_lo, v_lo, _ = fed.feature_round(
            params, fdata, rk, 32, mlp.per_sample_loss_from_h, mlp.client_h)
        np.testing.assert_allclose(float(v_sh), float(v_lo), rtol=1e-6)
        for a, b in zip(jax.tree.leaves(g_sh), jax.tree.leaves(g_lo)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-5)

        fl = FLConfig(batch_size=64, a1=0.9, a2=0.5, alpha_rho=0.1,
                      alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5)
        res = algorithms.algorithm3(
            mlp.per_sample_loss_from_h, mlp.client_h, params, fdata, fl,
            rounds=120, key=jax.random.PRNGKey(2), topology=topo)
        losses = np.asarray(res.history["round_loss_est"])
        assert losses[-1] < losses[0], losses
        print("OK", losses[0], "->", losses[-1])
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "OK" in proc.stdout

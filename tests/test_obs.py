"""obs/ subsystem invariants (DESIGN.md §13): an active MetricStream must be
a pure *observer* — trajectories and stacked (K,) histories bitwise-unchanged
versus ``obs=None`` — while every streamed row carries exactly the stacked
metric values (one float32 cast, both transports, both drivers, local and
sharded topologies). Plus the sink round-trips, the run manifest, eval-row
interleaving (and the no-silent-shadowing collision check in core/rounds),
and the launch/feature_dist deprecation shims.

On a single-device run (tier-1 CI) the sharded case degenerates to one
shard; the multi-device CI job (XLA_FLAGS=--xla_force_host_platform_
device_count=8) runs the same tests with real client distribution.
"""
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import make_codec
from repro.configs.base import FLConfig
from repro.core import algorithms, fed
from repro.core import rounds as rounds_lib
from repro.core.topology import feature_sharded_for, sharded_for
from repro.models import mlp
from repro.obs import (CsvSink, JsonlSink, MemorySink, MetricStream,
                       StdoutSink)
from repro.obs import sinks as obs_sinks

P, J, L = 12, 6, 3
I = 8                                   # sample clients; divisible by 1/2/4/8
K = 10                                  # rounds per run


def _fl(**kw):
    base = dict(batch_size=20, a1=0.9, a2=0.5, alpha_rho=0.1,
                alpha_gamma=0.6, tau=0.2)
    base.update(kw)
    return FLConfig(**base)


def _sample_data(key, n=240):
    z = jax.random.normal(key, (n, P))
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, L)
    return fed.partition_samples(z, jax.nn.one_hot(lab, L), I)


def _run_alg1(obs=None, driver="scan", topology=None, codec=None, rounds=K,
              eval_fn=None, eval_every=0):
    data = _sample_data(jax.random.PRNGKey(0))
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    return algorithms.algorithm1(mlp.per_sample_loss, params0, data, _fl(),
                                 rounds, jax.random.PRNGKey(2),
                                 eval_fn=eval_fn, eval_every=eval_every,
                                 driver=driver, codec=codec,
                                 topology=topology, obs=obs)


def _run_alg3(obs=None, codec=None, topology=None, rounds=K):
    key = jax.random.PRNGKey(3)
    z = jax.random.normal(key, (200, P))
    lab = jax.random.randint(jax.random.fold_in(key, 1), (200,), 0, L)
    data = fed.partition_features(z, jax.nn.one_hot(lab, L), 4)
    params0 = {"w0": jax.random.normal(key, (L, J)) * 0.2,
               "blocks": jax.random.normal(jax.random.fold_in(key, 2),
                                           (4, J, P // 4)) * 0.2}
    return algorithms.algorithm3(mlp.per_sample_loss_from_h, mlp.client_h,
                                 params0, data, _fl(), rounds,
                                 jax.random.PRNGKey(4), eval_every=0,
                                 codec=codec, topology=topology, obs=obs)


def _assert_bitwise(a, b, what):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.array_equal(np.asarray(x), np.asarray(y)), \
            f"{what} changed under an active stream"


def _assert_rows_match(rows, history, rounds, log_every=1):
    """Every streamed round row equals the f32-cast stacked history value."""
    round_rows = [r for r in rows if r["kind"] == "round"]
    expect_t = [t for t in range(1, rounds + 1) if t % log_every == 0]
    assert [r["t"] for r in round_rows] == expect_t
    names = [k for k in round_rows[0] if k not in ("kind", "t")]
    assert names, "round rows carry no metrics"
    for row in round_rows:
        for nm in names:
            want = float(np.float32(np.asarray(history["round_" + nm]
                                               [row["t"] - 1])))
            assert row[nm] == want, (nm, row["t"], row[nm], want)


# ---------------------------------------------------------------------------
# rows == stacked history, trajectories unchanged
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("driver,transport", [("scan", "future"),
                                              ("scan", "callback"),
                                              ("loop", "future")])
def test_stream_exact_and_pure(driver, transport):
    r_plain = _run_alg1(driver=driver)
    stream = MetricStream([MemorySink()], transport=transport)
    r_obs = _run_alg1(obs=stream, driver=driver)
    stream.sync()

    _assert_bitwise(r_plain.params, r_obs.params, "params")
    assert sorted(r_plain.history) == sorted(r_obs.history)
    for k in r_plain.history:
        _assert_bitwise(r_plain.history[k], r_obs.history[k],
                        f"history[{k!r}]")
    _assert_rows_match(stream.rows, r_plain.history, K)
    assert stream.rows == stream.sinks[0].rows


def test_stream_exact_sharded():
    topo = sharded_for(I)
    r_plain = _run_alg1(topology=topo)
    stream = MetricStream()
    r_obs = _run_alg1(obs=stream, topology=topo)
    stream.sync()
    _assert_bitwise(r_plain.params, r_obs.params, "params")
    _assert_rows_match(stream.rows, r_plain.history, K)


def test_log_every_thins_rows():
    stream = MetricStream(log_every=3)
    r = _run_alg1(obs=stream)
    stream.sync()
    _assert_rows_match(stream.rows, r.history, K, log_every=3)


def test_partial_flush_chunks():
    # flush_every that does not divide K: tail chunk still lands, in order
    stream = MetricStream(flush_every=7)
    r = _run_alg1(obs=stream, rounds=12)
    stream.sync()
    _assert_rows_match(stream.rows, r.history, 12)


def test_stream_with_codec_carries_ef_norm():
    codec = make_codec("int8")
    stream = MetricStream()
    _run_alg1(obs=stream, codec=codec)
    stream.sync()
    row = next(r for r in stream.rows if r["kind"] == "round")
    assert "ef_norm" in row and "stat_res" in row


def test_bad_transport_rejected():
    with pytest.raises(ValueError, match="transport"):
        MetricStream(transport="telegraph")


# ---------------------------------------------------------------------------
# feature (vertical) drivers
# ---------------------------------------------------------------------------


def test_feature_stream_exact_and_pure():
    r_plain = _run_alg3()
    stream = MetricStream()
    r_obs = _run_alg3(obs=stream)
    stream.sync()
    _assert_bitwise(r_plain.params, r_obs.params, "params")
    _assert_rows_match(stream.rows, r_plain.history, K)
    row = stream.rows[0]
    assert "stat_res" in row and "upload_bytes" in row


def test_feature_stream_sharded_with_codec():
    topo = feature_sharded_for(4)
    codec = make_codec("int8")
    stream = MetricStream()
    r = _run_alg3(obs=stream, codec=codec, topology=topo)
    stream.sync()
    _assert_rows_match(stream.rows, r.history, K)
    assert "ef_norm" in stream.rows[0]


# ---------------------------------------------------------------------------
# eval interleaving + the collision guard (core/rounds.py)
# ---------------------------------------------------------------------------


def test_eval_rows_interleaved_in_order():
    stream = MetricStream()
    _run_alg1(obs=stream, eval_fn=lambda p, s: {"test_acc": 0.5},
              eval_every=5)
    stream.sync()
    kinds_t = [(r["kind"], r["t"]) for r in stream.rows]
    # eval rows land right after their chunk's round rows, in t order
    assert kinds_t.index(("eval", 5)) == kinds_t.index(("round", 5)) + 1
    assert kinds_t.index(("eval", 10)) == kinds_t.index(("round", 10)) + 1
    evals = [r for r in stream.rows if r["kind"] == "eval"]
    assert [r["test_acc"] for r in evals] == [0.5, 0.5]


def test_eval_metric_collision_raises():
    # an eval hook must not silently overwrite a per-round scan series
    with pytest.raises(ValueError, match="round_loss_est"):
        _run_alg1(eval_fn=lambda p, s: {"round_loss_est": 0.0}, eval_every=5)
    with pytest.raises(ValueError, match="round"):
        _run_alg1(eval_fn=lambda p, s: {"round": 0.0}, eval_every=5)


def test_emit_event_direct_and_queued():
    stream = MetricStream([MemorySink()])
    stream.emit_event({"kind": "span", "span": "setup", "dur_s": 0.1})
    _run_alg1(obs=stream, rounds=3)
    stream.emit_event({"kind": "span", "span": "teardown", "dur_s": 0.2})
    stream.sync()
    kinds = [r["kind"] for r in stream.rows]
    assert kinds[0] == "span" and kinds[-1] == "span"
    assert kinds[1:-1] == ["round"] * 3


# ---------------------------------------------------------------------------
# sinks + manifest
# ---------------------------------------------------------------------------


def test_jsonl_sink_roundtrip(tmp_path):
    path = str(tmp_path / "rows.jsonl")
    stream = MetricStream([JsonlSink(path)])
    _run_alg1(obs=stream, rounds=4)
    stream.close()
    with open(path) as f:
        disk = [json.loads(line) for line in f]
    assert disk == stream.rows


def test_csv_and_stdout_sinks(tmp_path, capsys):
    path = str(tmp_path / "rows.csv")
    stream = MetricStream([CsvSink(path), StdoutSink(prefix="obs ")])
    _run_alg1(obs=stream, rounds=3)
    stream.close()
    lines = open(path).read().splitlines()
    assert len(lines) == 4 and "loss_est" in lines[0]   # header + 3 rows
    out = capsys.readouterr().out
    assert out.count("obs ") == 3 and "loss_est=" in out


def test_run_manifest_contents(tmp_path):
    path = str(tmp_path / "m.json")
    obs_sinks.write_manifest(path, config=_fl(), codec=make_codec("int8"),
                             topology=sharded_for(I),
                             cost={"flops": 123, "bytes": 456})
    man = json.load(open(path))
    assert man["codec"] == "int8"
    assert man["jax_version"] == jax.__version__
    assert man["hlo_cost"] == {"flops": 123, "bytes": 456}
    assert man["config"]["batch_size"] == 20
    assert man["topology"]["name"] == "sharded"
    assert man["topology"]["num_shards"] >= 1
    assert isinstance(man["git_sha"], str)


# ---------------------------------------------------------------------------
# launch/feature_dist deprecation shims
# ---------------------------------------------------------------------------


def test_feature_dist_deprecation_warns_once():
    from repro.launch import feature_dist
    from repro.launch.mesh import make_feature_mesh

    mesh = make_feature_mesh(1)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        feature_dist.make_feature_round(mesh, mlp.per_sample_loss_from_h,
                                        mlp.client_h)
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1
    assert "--mode feature" in str(dep[0].message)
    assert "make_feature_round" in str(dep[0].message)
    # the shim message carries the lint rule code so the runtime warning
    # and `python -m repro.analysis` point at the same rule
    assert str(dep[0].message).startswith("[FLT004]")

"""Constrained federated training of a language model — the paper's Algorithm 2
applied to the model zoo: min ‖ω‖² s.t. mean-loss <= U (formulation (40)).

    PYTHONPATH=src python examples/constrained_lm_finetune.py \
        --arch qwen2.5-3b --smoke --steps 120 --cost-limit 4.5

Shows the constrained SSCA dynamics on a transformer: the dual ν activates
while the loss is above U, then the iterate rides the constraint boundary
while the parameter norm shrinks (Theorem 2 behaviour on a real model).
"""
import argparse

from repro.configs.base import FLConfig
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--cost-limit", type=float, default=4.5)
    args = ap.parse_args()

    fl = FLConfig(a1=0.9, a2=0.5, alpha_rho=0.1, alpha_gamma=0.6, tau=0.2,
                  constrained=True, cost_limit=args.cost_limit, penalty_c=1e4)
    state, logs = train_loop(args.arch, args.steps, args.batch, args.seq,
                             smoke=args.smoke, constrained=True, fl=fl,
                             log_every=10)
    last = logs[-1]
    print(f"\nfinal: loss={last['loss']:.4f} (U={args.cost_limit}) "
          f"nu={last['nu']:.3f} slack={last['slack']:.2e} l2={last['l2']:.2f}")
    if last["loss"] <= args.cost_limit * 1.1:
        print("constraint satisfied — model norm minimized subject to the "
              "loss budget.")
    else:
        print("constraint not yet met — increase --steps or U.")


if __name__ == "__main__":
    main()

"""Vertical (feature-based) FL on a device mesh: Algorithms 3/4 through the
shared topology + scan engine (DESIGN.md §12) — each feature client resident
on its own "model"-axis shard, the paper's step-4 h-exchange as a tiled
all_gather, and K rounds compiled to one dispatch.

    PYTHONPATH=src python examples/vertical_fl_distributed.py --clients 4
    PYTHONPATH=src python examples/vertical_fl_distributed.py --clients 4 \
        --constrained --cost-limit 1.2 --codec int8

Uses virtual host devices so it runs anywhere; on a real cluster the same
code maps clients onto physical chips. ``--topology local`` runs the same
mathematics as a single-device vmap — the trajectories agree bit-for-bit
(tests/test_feature_topology.py pins it).
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--topology", choices=("sharded", "local"),
                    default="sharded")
    ap.add_argument("--constrained", action="store_true",
                    help="run Algorithm 4: min ‖ω‖² s.t. loss <= U (40)")
    ap.add_argument("--cost-limit", type=float, default=1.2,
                    help="U for --constrained")
    ap.add_argument("--codec", choices=("none", "int8", "int4", "topk"),
                    default="none",
                    help="compress the head + block q-uploads")
    ap.add_argument("--driver", choices=("scan", "loop"), default="scan")
    args = ap.parse_args()

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.clients}")

    from repro.launch.train import feature_train_loop

    print(f"{args.clients} feature clients, topology={args.topology}"
          + (", constrained (Algorithm 4)" if args.constrained
             else " (Algorithm 3)"))
    result = feature_train_loop(
        clients=args.clients, rounds=args.rounds,
        constrained=args.constrained, cost_limit=args.cost_limit,
        topology=args.topology, codec=args.codec, driver=args.driver,
        log_every=max(args.rounds // 10, 1))
    print("h-exchange per round: (I x B x J) floats all-gathered over the "
          "model axis (the paper's Alg-3 step 4); "
          f"axis bytes/round = {float(result.history['round_axis_bytes'][0]):.0f}")


if __name__ == "__main__":
    main()

"""Vertical (feature-based) FL on a device mesh: Algorithm 3 with each
feature client resident on its own "model"-axis shard (shard_map + psum
h-exchange — the distributed realization of the paper's §IV).

    PYTHONPATH=src python examples/vertical_fl_distributed.py --clients 4

Uses virtual host devices so it runs anywhere; on a real cluster the same
code maps clients onto physical chips.
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=300)
    args = ap.parse_args()

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.clients}")

    import jax
    import jax.numpy as jnp
    from repro.configs.base import FLConfig
    from repro.core import fed
    from repro.data.synthetic import classification_dataset
    from repro.launch.feature_dist import train_feature_distributed
    from repro.models import mlp

    mesh = jax.make_mesh((args.clients,), ("model",))
    key = jax.random.PRNGKey(0)
    print(f"{args.clients} feature clients, one per mesh shard")
    (z, y, _), _ = classification_dataset(key, n=8000, num_features=128,
                                          num_classes=10, test_n=10, noise=4.0)
    fdata = fed.partition_features(z, y, args.clients)
    pi = fdata.feature_blocks.shape[-1]
    w0 = jax.random.normal(key, (10, 32)) * 0.2
    blocks = jax.random.normal(jax.random.fold_in(key, 1),
                               (args.clients, 32, pi)) * 0.2
    fl = FLConfig(batch_size=64, a1=0.9, a2=0.5, alpha_rho=0.1,
                  alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5, mode="feature")
    params, losses = train_feature_distributed(
        mesh, mlp.per_sample_loss_from_h, mlp.client_h, w0, blocks,
        fdata.feature_blocks, fdata.labels, fl, rounds=args.rounds,
        key=jax.random.PRNGKey(2))
    print("per-checkpoint batch loss:", [round(l, 4) for l in losses])
    print("h-exchange per round: B x J floats over the model axis "
          "(the paper's Alg-3 step 4, as a psum)")


if __name__ == "__main__":
    main()

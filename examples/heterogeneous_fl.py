"""Heterogeneous federated learning: non-IID clients + partial participation
+ compressed uploads.

    PYTHONPATH=src python examples/heterogeneous_fl.py [--rounds 200] [--n 20000]

The paper's convergence theory (Theorems 1-4) is stated for heterogeneous
client datasets (N_i varies) and holds under unbiased gradient estimates —
which per-round client sampling preserves (fed.aggregation_weights). This
example sweeps the three practical-FL axes the companion literature
emphasizes:

  * statistical heterogeneity: Dirichlet(α) label-skew partitions with
    α ∈ {0.1 (near single-class clients), 100 (≈IID)}, ragged N_i;
  * systems heterogeneity: S = 3 of I = 10 clients participating per round,
    aggregation reweighted by I/S to stay unbiased;
  * communication budget: dense fp32 uploads vs int8 stochastic quantization
    (unbiased) vs top-k sparsification with error feedback (DESIGN.md §10),
    with exact per-round upload bytes from repro.comm.accounting;
  * client topology (DESIGN.md §11): --topologies local,sharded sweeps the
    client-execution engine, so the non-IID Dirichlet partitions (ragged
    N_i, masked batches) run both under single-device vmap and distributed
    over the host mesh with the N_i/(B_i·N) aggregation as a weighted psum
    (set XLA_FLAGS=--xla_force_host_platform_device_count=8 to actually
    spread the clients; a 1-device mesh still runs the collective path).

All scenario cells run Algorithm 1 through the scan-compiled round driver
(one XLA dispatch per eval chunk) and print final cost/accuracy/bytes.
"""
import argparse

import jax

from repro.comm import make_codec
from repro.configs.base import FLConfig
from repro.core import algorithms, fed
from repro.core.topology import sharded_for
from repro.data.synthetic import classification_dataset
from repro.models import mlp


def _make_topology(name: str, clients: int):
    """"local" -> None (the default engine); "sharded" -> a ShardedTopology
    over the most host devices that divide the client count."""
    return None if name == "local" else sharded_for(clients)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--participation", type=int, default=3)
    ap.add_argument("--codecs", default="none,int8,topk",
                    help="comma-separated codec axis "
                         "(none|identity|int8|int4|topk|topk8)")
    ap.add_argument("--topologies", default="local,sharded",
                    help="comma-separated topology axis (local|sharded)")
    ap.add_argument("--topk-frac", type=float, default=0.05)
    args = ap.parse_args()
    if args.rounds < 1 or args.participation < 1:
        ap.error("--rounds and --participation must be >= 1")
    codec_names = [c.strip() for c in args.codecs.split(",") if c.strip()]
    topo_names = [t.strip() for t in args.topologies.split(",") if t.strip()]

    key = jax.random.PRNGKey(0)
    print(f"building synthetic dataset (N={args.n}, P=784, L=10) ...")
    (z, y, _), (zt, _, labt) = classification_dataset(
        key, n=args.n, num_features=784, num_classes=10, test_n=2_000,
        noise=4.0)
    params0 = mlp.init(jax.random.PRNGKey(1), 784, 64, 10)
    fl = FLConfig(num_clients=args.clients, batch_size=100, a1=0.3, a2=0.3,
                  alpha_rho=0.1, alpha_gamma=0.6, tau=0.05, l2_lambda=1e-5)

    def eval_fn(params, state):
        return {"cost": float(mlp.mean_loss(params, z[:4000], y[:4000])),
                "acc": float(mlp.accuracy(params, zt, labt))}

    scenarios = []
    for alpha, tag in ((100.0, "near-IID"), (0.1, "pathological non-IID")):
        data = fed.partition_dirichlet(z, y, args.clients,
                                       jax.random.fold_in(key, 3), alpha=alpha)
        counts = [int(c) for c in data.counts]
        print(f"\nDirichlet(alpha={alpha}) [{tag}]  N_i = {counts}")
        for part in (None, args.participation):
            for cname in codec_names:
                for tname in topo_names:
                    topo = _make_topology(tname, args.clients)
                    shards = getattr(topo, "num_shards", 1)
                    codec = make_codec(cname, topk_frac=args.topk_frac)
                    label = (f"alpha={alpha:<5g} S={part or args.clients}/"
                             f"{args.clients} codec={cname:<5s} "
                             f"topo={tname}x{shards}")
                    r = algorithms.algorithm1(
                        mlp.per_sample_loss, params0, data, fl, args.rounds,
                        jax.random.PRNGKey(2), eval_fn=eval_fn,
                        eval_every=args.rounds, participation=part,
                        codec=codec, topology=topo)
                    cost = float(r.history["cost"][-1])
                    acc = float(r.history["acc"][-1])
                    up_mb = float(r.history["round_upload_bytes"].sum()) / 1e6
                    ax_mb = float(r.history["round_axis_bytes"].sum()) / 1e6
                    scenarios.append((label, cost, acc, up_mb, ax_mb))
                    print(f"  {label}  cost={cost:.4f}  acc={acc:.4f}  "
                          f"upload={up_mb:.1f}MB  axis={ax_mb:.1f}MB")

    print("\nscenario summary (Algorithm 1, scan driver):")
    for label, cost, acc, up_mb, ax_mb in scenarios:
        print(f"  {label}  cost={cost:.4f}  acc={acc:.4f}  "
              f"upload={up_mb:.1f}MB  axis={ax_mb:.1f}MB")


if __name__ == "__main__":
    main()

"""Batched serving demo: prefill + greedy decode with KV caches / SSM states.

    PYTHONPATH=src python examples/serve_batched.py --arch zamba2-1.2b --smoke
    PYTHONPATH=src python examples/serve_batched.py --arch xlstm-1.3b --smoke

The SSM/hybrid architectures decode with O(1) state — the same code path the
long_500k dry-run shape exercises at 524288-token context.
"""
import argparse

from repro.launch.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    seqs, stats = generate(args.arch, smoke=args.smoke, batch=args.batch,
                           prompt_len=args.prompt_len, gen=args.gen)
    print(f"arch={args.arch} generated {seqs.shape[0]}x{seqs.shape[1]} tokens")
    print("first sequence:", seqs[0].tolist())
    print(f"throughput: {stats['tokens_per_s']:.1f} tok/s (CPU, smoke config)")


if __name__ == "__main__":
    main()

"""Quickstart: federated training with mini-batch SSCA (paper Algorithm 1).

    PYTHONPATH=src python examples/quickstart.py [--rounds 300] [--n 20000]

Ten clients collaboratively train the paper's two-layer swish network on a
synthetic MNIST-shaped classification task; compares against FedSGD at the
same per-round computation and prints the per-round training cost.
"""
import argparse

import jax

from repro.configs.base import FLConfig
from repro.core import algorithms, baselines, fed
from repro.core.baselines import SGDConfig
from repro.data.synthetic import classification_dataset
from repro.models import mlp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--n", type=int, default=20_000)
    args = ap.parse_args()
    if args.rounds < 1 or args.n < 100:
        ap.error("--rounds must be >= 1 and --n >= 100")
    rounds = args.rounds
    key = jax.random.PRNGKey(0)
    print(f"building synthetic dataset (N={args.n}, P=784, L=10) ...")
    (z, y, _), (zt, _, labt) = classification_dataset(
        key, n=args.n, num_features=784, num_classes=10, test_n=2_000,
        noise=4.0)
    params0 = mlp.init(jax.random.PRNGKey(1), 784, 64, 10)
    data = fed.partition_samples(z, y, num_clients=10)

    def eval_fn(params, state):
        return {"cost": float(mlp.mean_loss(params, z[:4000], y[:4000])),
                "acc": float(mlp.accuracy(params, zt, labt))}

    fl = FLConfig(num_clients=10, batch_size=100, a1=0.3, a2=0.3,
                  alpha_rho=0.1, alpha_gamma=0.6, tau=0.05, l2_lambda=1e-5)
    print(f"running Algorithm 1 (mini-batch SSCA) for {rounds} rounds ...")
    r = algorithms.algorithm1(
        lambda p, zz, yy: mlp.per_sample_loss(p, zz, yy),
        params0, data, fl, rounds=rounds, key=jax.random.PRNGKey(2),
        eval_fn=eval_fn, eval_every=max(1, rounds // 6))
    for i, rd in enumerate(r.history["round"]):
        print(f"  round {int(rd):4d}  cost={float(r.history['cost'][i]):.4f}"
              f"  acc={float(r.history['acc'][i]):.4f}")

    print("running FedSGD baseline (same per-round compute) ...")
    b = baselines.sample_sgd(
        lambda p, zz, yy: mlp.per_sample_loss(p, zz, yy),
        params0, data, SGDConfig(lr_a=0.3, lr_alpha=0.3, local_batch=100),
        rounds=rounds, key=jax.random.PRNGKey(2), eval_fn=eval_fn,
        eval_every=rounds)
    print(f"  FedSGD final cost={float(b.history['cost'][-1]):.4f}")
    print(f"  SSCA   final cost={float(r.history['cost'][-1]):.4f}  "
          "<- faster per communication round (paper Fig. 1)")


if __name__ == "__main__":
    main()

"""End-to-end driver reproducing the paper's §VI experiment suite at the
paper's own scale (N=60000, I=10, K=784, J=128, L=10, T=1000): all four
algorithms + SGD/SGD-m baselines, histories written to CSV.

    PYTHONPATH=src python examples/paper_experiments.py [--rounds 1000] \
        [--n 60000] [--out results/paper]

This is the paper-faithful reproduction run (the paper trains a ~100k-param
model for T=1000 communication rounds; that IS this paper's "end-to-end
training driver"). Expect ~20-40 min on one CPU core at full scale; use
--rounds 200 --n 20000 for a quick pass.
"""
import argparse
import csv
import os

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core import algorithms, baselines, fed
from repro.core.baselines import SGDConfig
from repro.data.synthetic import classification_dataset
from repro.models import mlp


def write_history(path, hist):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    # eval-series columns only: the driver also returns full per-round
    # "round_*" series of length `rounds`, which would misalign these rows
    n = len(hist["round"])
    keys = sorted(k for k in hist if len(hist[k]) == n and not
                  k.startswith("round_"))
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(keys)
        for i in range(n):
            w.writerow([float(hist[k][i]) for k in keys])
    print("wrote", path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=1000)
    ap.add_argument("--n", type=int, default=60_000)
    ap.add_argument("--batch", type=int, default=100)
    ap.add_argument("--cost-limit", type=float, default=0.5)
    ap.add_argument("--out", default="results/paper")
    args = ap.parse_args()

    P, J, L, I = 784, 128, 10, 10
    key = jax.random.PRNGKey(0)
    (z, y, _), (zt, _, labt) = classification_dataset(
        key, n=args.n, num_features=P, num_classes=L, test_n=10_000, noise=4.0)
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    data = fed.partition_samples(z, y, I)
    fdata = fed.partition_features(z, y, I)
    pi = fdata.feature_blocks.shape[-1]
    w1p = jnp.pad(params0["w1"], ((0, 0), (0, I * pi - P)))
    fparams0 = {"w0": params0["w0"],
                "blocks": w1p.reshape(J, I, pi).transpose(1, 0, 2)}

    def psl(p, zz, yy):
        return mlp.per_sample_loss(p, zz, yy)

    def ev(params, state):
        out = {"cost": float(mlp.mean_loss(params, z[:5000], y[:5000])),
               "acc": float(mlp.accuracy(params, zt, labt)),
               "l2": float(mlp.l2_sq(params))}
        if hasattr(state, "slack"):
            out["slack"] = float(state.slack)
        return out

    def fev(p, state):
        hsum = sum(mlp.client_h(p["blocks"][i], fdata.feature_blocks[i][:5000])
                   for i in range(I))
        out = {"cost": float(jnp.mean(mlp.per_sample_loss_from_h(
            p["w0"], hsum, y[:5000])))}
        if hasattr(state, "slack"):
            out["slack"] = float(state.slack)
        return out

    every = max(args.rounds // 20, 1)
    fl_u = FLConfig(batch_size=args.batch, a1=0.3, a2=0.3, alpha_rho=0.1,
                    alpha_gamma=0.6, tau=0.05, l2_lambda=1e-5)
    fl_c = FLConfig(batch_size=args.batch, a1=0.9, a2=0.5, alpha_rho=0.1,
                    alpha_gamma=0.6, tau=0.2, constrained=True,
                    cost_limit=args.cost_limit, penalty_c=1e5)

    print("== Algorithm 1 (unconstrained sample-based SSCA)")
    r = algorithms.algorithm1(psl, params0, data, fl_u, args.rounds,
                              jax.random.PRNGKey(2), ev, every)
    write_history(f"{args.out}/alg1.csv", r.history)

    print("== FedSGD / SGD-m baselines")
    r = baselines.sample_sgd(psl, params0, data,
                             SGDConfig(lr_a=0.3, lr_alpha=0.3,
                                       local_batch=args.batch),
                             args.rounds, jax.random.PRNGKey(2), ev, every)
    write_history(f"{args.out}/fedsgd.csv", r.history)
    r = baselines.sample_sgd(psl, params0, data,
                             SGDConfig(lr_a=0.3, lr_alpha=0.0, momentum=0.1,
                                       local_steps=5,
                                       local_batch=max(args.batch // 5, 2)),
                             args.rounds, jax.random.PRNGKey(2), ev, every,
                             momentum=True)
    write_history(f"{args.out}/sgdm.csv", r.history)

    print("== Algorithm 2 (constrained sample-based SSCA)")
    r = algorithms.algorithm2(psl, params0, data, fl_c, args.rounds,
                              jax.random.PRNGKey(3), ev, every)
    write_history(f"{args.out}/alg2.csv", r.history)

    print("== Algorithm 3 (unconstrained feature-based SSCA)")
    r = algorithms.algorithm3(mlp.per_sample_loss_from_h, mlp.client_h,
                              fparams0, fdata, fl_u, args.rounds,
                              jax.random.PRNGKey(4), fev, every)
    write_history(f"{args.out}/alg3.csv", r.history)

    print("== Algorithm 4 (constrained feature-based SSCA)")
    r = algorithms.algorithm4(mlp.per_sample_loss_from_h, mlp.client_h,
                              fparams0, fdata, fl_c, args.rounds,
                              jax.random.PRNGKey(5), fev, every)
    write_history(f"{args.out}/alg4.csv", r.history)
    print("done.")


if __name__ == "__main__":
    main()

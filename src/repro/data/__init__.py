from repro.data.synthetic import (classification_dataset, token_dataset,
                                  make_batch_iterator)  # noqa: F401

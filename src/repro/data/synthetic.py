"""Synthetic datasets (the container is offline; MNIST is unavailable).

`classification_dataset` mirrors MNIST's dimensions (N=60000, P=784, L=10) as
class-conditional Gaussians over random class prototypes — a nonconvex-loss
classification task of the same shape, so all the paper's *relative* claims
(convergence ordering, comm/comp tradeoffs, constrained feasibility) can be
validated. Deterministic given the seed.

`token_dataset` produces LM token streams (Zipf-ish marginals with a Markov
bigram structure) for the model-zoo training examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def classification_dataset(key, n: int = 60_000, num_features: int = 784,
                           num_classes: int = 10, noise: float = 1.0,
                           test_n: int = 10_000):
    kp, kl, kn, klt, knt = jax.random.split(key, 5)
    protos = jax.random.normal(kp, (num_classes, num_features)) / jnp.sqrt(num_features)

    def make(klab, knoise, count):
        labels = jax.random.randint(klab, (count,), 0, num_classes)
        z = protos[labels] + noise * jax.random.normal(
            knoise, (count, num_features)) / jnp.sqrt(num_features)
        y = jax.nn.one_hot(labels, num_classes)
        return z, y, labels

    train = make(kl, kn, n)
    test = make(klt, knt, test_n)
    return train, test


def federated_classification_dataset(key, num_clients: int, n: int = 60_000,
                                     num_features: int = 784,
                                     num_classes: int = 10, noise: float = 1.0,
                                     test_n: int = 10_000,
                                     dirichlet_alpha: float = None):
    """classification_dataset pre-partitioned into client shards.

    dirichlet_alpha=None gives the seed's IID equal shards; a float α draws
    the standard Dirichlet(α) label-skew partition (fed.partition_dirichlet),
    producing ragged non-IID N_i — the statistical-heterogeneity regime the
    paper's Theorems 1-4 cover (N_i varies).

    Returns (SampleFedData, (z_train, y_train, labels), (z_test, y_test,
    labels_test)).
    """
    from repro.core import fed

    train, test = classification_dataset(key, n=n, num_features=num_features,
                                         num_classes=num_classes, noise=noise,
                                         test_n=test_n)
    z, y, _ = train
    pkey = jax.random.fold_in(key, 0xfed)
    if dirichlet_alpha is None:
        data = fed.partition_samples(z, y, num_clients, key=pkey)
    else:
        data = fed.partition_dirichlet(z, y, num_clients, pkey,
                                       alpha=dirichlet_alpha)
    return data, train, test


class VirtualFedData:
    """Virtual federated population: client shards DERIVED on the fly from
    (base key, client id) instead of stored — so ``--clients 1000000`` never
    materializes a dataset (DESIGN.md §14).

    Statistics match `federated_classification_dataset`'s heterogeneity
    regime: class-conditional Gaussians over shared prototypes, per-client
    label skew probs ~ Dirichlet(α·1_L), ragged shard sizes
    N_i ~ Uniform{n_min..n_max}. Every row is a pure deterministic function
    of (key, client id, row index), so

    * the O(S) cohort engine can ask for exactly the cohort's rows
      (`counts_for`/`batch_rows`/`shards_for` — the same three-method data
      view `core.fed.SampleFedData` implements by gathering), touching O(S)
      state per round, and
    * `materialize()` produces the bit-identical dense `SampleFedData`
      (same row values, same zero padding) for small populations — the
      equality reference tests/test_cohort.py and benchmarks/scale_bench.py
      pin the cohort engine against.

    ``total`` (the population sample count N in eq. 9's weights) is reduced
    once at construction in fixed-size id chunks — no (I,)-shaped array is
    ever built, keeping construction O(I/chunk) dispatches and O(chunk)
    memory even at I = 1e6.
    """

    def __init__(self, key, num_clients: int, n_min: int = 8,
                 n_max: int = 32, num_features: int = 16,
                 num_classes: int = 4, noise: float = 1.0,
                 alpha: float = 0.5):
        if n_min < 1 or n_max < n_min:
            raise ValueError(f"need 1 <= n_min <= n_max, got [{n_min}, {n_max}]")
        self.key = key
        self.num_clients = int(num_clients)
        self.n_min, self.n_max = int(n_min), int(n_max)
        self.num_features, self.num_classes = int(num_features), int(num_classes)
        self.noise, self.alpha = float(noise), float(alpha)
        self.protos = (jax.random.normal(
            jax.random.fold_in(key, 0x9707), (num_classes, num_features))
            / jnp.sqrt(num_features))
        self.total = int(self._population_total())

    # -- per-client generators (each a pure function of the client id) -----

    def _client_key(self, i):
        return jax.random.fold_in(self.key, i)

    def _count(self, i):
        """True N_i ~ Uniform{n_min..n_max}, keyed by client id."""
        ck = self._client_key(i)
        return (self.n_min + jax.random.randint(
            jax.random.fold_in(ck, 2), (), 0, self.n_max - self.n_min + 1)
        ).astype(jnp.int32)

    def _log_probs(self, ck):
        """Client label-skew: log p ~ log Dirichlet(α·1_L)."""
        probs = jax.random.dirichlet(
            jax.random.fold_in(ck, 1),
            self.alpha * jnp.ones((self.num_classes,)))
        return jnp.log(probs)

    def _row(self, ck, log_probs, r):
        """Row r of a client's shard: label ~ Categorical(p_client), feature
        = prototype + Gaussian noise. Purely (client key, row index)-keyed,
        so cohort gathers and dense materialization agree bitwise."""
        kr = jax.random.fold_in(jax.random.fold_in(ck, 3), r)
        label = jax.random.categorical(kr, log_probs)
        z = (self.protos[label] + self.noise * jax.random.normal(
            jax.random.fold_in(kr, 1), (self.num_features,))
            / jnp.sqrt(self.num_features))
        return z, jax.nn.one_hot(label, self.num_classes)

    def _client_rows(self, i, idx):
        ck = self._client_key(i)
        lp = self._log_probs(ck)
        return jax.vmap(lambda r: self._row(ck, lp, r))(idx)

    def _population_total(self):
        """Σ_i N_i reduced in 4096-id chunks — never an (I,) array."""
        chunk = 4096
        num_chunks = -(-self.num_clients // chunk)

        def body(c, acc):
            ids = c * chunk + jnp.arange(chunk, dtype=jnp.int32)
            counts = jax.vmap(self._count)(ids)
            return acc + jnp.sum(
                jnp.where(ids < self.num_clients, counts, 0))

        return jax.lax.fori_loop(0, num_chunks, body, jnp.zeros((), jnp.int32))

    # -- the cohort data view (same contract as SampleFedData) -------------

    def counts_for(self, ids):
        """(S,) true N_i for the given client ids."""
        return jax.vmap(self._count)(ids)

    def batch_rows(self, ids, idx):
        """(S,) ids + (S, B) row indices -> ((S, B, P), (S, B, L)), each row
        generated directly — bitwise what `materialize()` would store."""
        return jax.vmap(self._client_rows)(ids, idx)

    def shards_for(self, ids):
        """Full padded shards for the cohort: rows r >= N_i are zero, exactly
        matching the dense container's padding convention."""
        counts = self.counts_for(ids)
        rows = jnp.arange(self.n_max, dtype=jnp.int32)
        feats, labs = jax.vmap(
            lambda i: self._client_rows(i, rows))(ids)
        valid = (rows[None, :] < counts[:, None])
        return (feats * valid[:, :, None], labs * valid[:, :, None], counts)

    def materialize(self, max_scalars: int = 50_000_000):
        """Dense `SampleFedData` with identical row values and padding — the
        small-I equality reference. Refuses population sizes whose dense
        form would not fit (that regime is the whole point of this class)."""
        from repro.core import fed

        scalars = (self.num_clients * self.n_max
                   * (self.num_features + self.num_classes))
        if scalars > max_scalars:
            raise ValueError(
                f"materialize() would build ~{scalars:.2e} scalars for "
                f"I={self.num_clients} — the virtual view exists so this "
                "never happens; use the cohort engine instead")
        ids = jnp.arange(self.num_clients, dtype=jnp.int32)
        feats, labs, counts = self.shards_for(ids)
        return fed.SampleFedData(feats, labs, counts)


def token_dataset(key, vocab_size: int, n_tokens: int, order: int = 1):
    """Markov bigram stream: next-token depends on current via a random sparse
    transition; gives a learnable LM signal with nonzero optimal loss."""
    kt, ks = jax.random.split(key)
    fanout = 4
    nexts = jax.random.randint(kt, (vocab_size, fanout), 0, vocab_size)

    def step(tok, k):
        choice = jax.random.randint(k, (), 0, fanout)
        nxt = nexts[tok, choice]
        return nxt, nxt

    _, toks = jax.lax.scan(step, jnp.zeros((), jnp.int32),
                           jax.random.split(ks, n_tokens))
    return toks


def sample_window(tokens, key, batch: int, seq: int):
    """One {tokens, targets} batch of random (seq+1)-token windows. Pure and
    traceable — the scan-compiled train driver calls it inside jit."""
    n = tokens.shape[0] - seq - 1
    starts = jax.random.randint(key, (batch,), 0, n)
    idx = starts[:, None] + jnp.arange(seq + 1)[None, :]
    window = tokens[idx]
    return {"tokens": window[:, :-1], "targets": window[:, 1:]}


def make_batch_iterator(tokens, batch: int, seq: int, key):
    """Infinite iterator of {tokens, targets} windows."""
    while True:
        key, sub = jax.random.split(key)
        yield sample_window(tokens, sub, batch, seq)

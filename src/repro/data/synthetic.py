"""Synthetic datasets (the container is offline; MNIST is unavailable).

`classification_dataset` mirrors MNIST's dimensions (N=60000, P=784, L=10) as
class-conditional Gaussians over random class prototypes — a nonconvex-loss
classification task of the same shape, so all the paper's *relative* claims
(convergence ordering, comm/comp tradeoffs, constrained feasibility) can be
validated. Deterministic given the seed.

`token_dataset` produces LM token streams (Zipf-ish marginals with a Markov
bigram structure) for the model-zoo training examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def classification_dataset(key, n: int = 60_000, num_features: int = 784,
                           num_classes: int = 10, noise: float = 1.0,
                           test_n: int = 10_000):
    kp, kl, kn, klt, knt = jax.random.split(key, 5)
    protos = jax.random.normal(kp, (num_classes, num_features)) / jnp.sqrt(num_features)

    def make(klab, knoise, count):
        labels = jax.random.randint(klab, (count,), 0, num_classes)
        z = protos[labels] + noise * jax.random.normal(
            knoise, (count, num_features)) / jnp.sqrt(num_features)
        y = jax.nn.one_hot(labels, num_classes)
        return z, y, labels

    train = make(kl, kn, n)
    test = make(klt, knt, test_n)
    return train, test


def federated_classification_dataset(key, num_clients: int, n: int = 60_000,
                                     num_features: int = 784,
                                     num_classes: int = 10, noise: float = 1.0,
                                     test_n: int = 10_000,
                                     dirichlet_alpha: float = None):
    """classification_dataset pre-partitioned into client shards.

    dirichlet_alpha=None gives the seed's IID equal shards; a float α draws
    the standard Dirichlet(α) label-skew partition (fed.partition_dirichlet),
    producing ragged non-IID N_i — the statistical-heterogeneity regime the
    paper's Theorems 1-4 cover (N_i varies).

    Returns (SampleFedData, (z_train, y_train, labels), (z_test, y_test,
    labels_test)).
    """
    from repro.core import fed

    train, test = classification_dataset(key, n=n, num_features=num_features,
                                         num_classes=num_classes, noise=noise,
                                         test_n=test_n)
    z, y, _ = train
    pkey = jax.random.fold_in(key, 0xfed)
    if dirichlet_alpha is None:
        data = fed.partition_samples(z, y, num_clients, key=pkey)
    else:
        data = fed.partition_dirichlet(z, y, num_clients, pkey,
                                       alpha=dirichlet_alpha)
    return data, train, test


def token_dataset(key, vocab_size: int, n_tokens: int, order: int = 1):
    """Markov bigram stream: next-token depends on current via a random sparse
    transition; gives a learnable LM signal with nonzero optimal loss."""
    kt, ks = jax.random.split(key)
    fanout = 4
    nexts = jax.random.randint(kt, (vocab_size, fanout), 0, vocab_size)

    def step(tok, k):
        choice = jax.random.randint(k, (), 0, fanout)
        nxt = nexts[tok, choice]
        return nxt, nxt

    _, toks = jax.lax.scan(step, jnp.zeros((), jnp.int32),
                           jax.random.split(ks, n_tokens))
    return toks


def sample_window(tokens, key, batch: int, seq: int):
    """One {tokens, targets} batch of random (seq+1)-token windows. Pure and
    traceable — the scan-compiled train driver calls it inside jit."""
    n = tokens.shape[0] - seq - 1
    starts = jax.random.randint(key, (batch,), 0, n)
    idx = starts[:, None] + jnp.arange(seq + 1)[None, :]
    window = tokens[idx]
    return {"tokens": window[:, :-1], "targets": window[:, 1:]}


def make_batch_iterator(tokens, batch: int, seq: int, key):
    """Infinite iterator of {tokens, targets} windows."""
    while True:
        key, sub = jax.random.split(key)
        yield sample_window(tokens, sub, batch, seq)

"""xLSTM language model (arXiv:2405.04517): repeating groups of mLSTM blocks with
an sLSTM block closing each group. 48L = 6 groups x (7 mLSTM + 1 sLSTM).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import ssm


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _groups(cfg):
    unit = len(cfg.block_pattern) or 8
    n_m = (cfg.block_pattern or ("m",) * 7 + ("s",)).count("m")
    g = max(1, cfg.n_layers // unit)
    return g, n_m, unit - n_m  # groups, m per group, s per group


def init(key, cfg):
    dt = _dt(cfg)
    g, n_m, n_s = _groups(cfg)
    k_e, k_m, k_s = jax.random.split(key, 3)
    mk = jax.random.split(k_m, g * n_m).reshape(g, n_m, 2)
    sk = jax.random.split(k_s, g * max(1, n_s)).reshape(g, max(1, n_s), 2)
    params = {
        "embed": L.embed_init(k_e, (cfg.vocab_size, cfg.d_model), dt),
        "m_blocks": jax.vmap(jax.vmap(lambda k: ssm.mlstm_init(k, cfg, dt)))(mk),
        "ln_f": L.rmsnorm_init(cfg.d_model, dt),
    }
    if n_s:
        params["s_blocks"] = jax.vmap(jax.vmap(lambda k: ssm.slstm_init(k, cfg, dt)))(sk)
    return params


def backbone(params, x, cfg):
    g, n_m, n_s = _groups(cfg)

    def group(h, gp):
        def m_body(h, mp):
            return L.shard_batch(ssm.mlstm_block(mp, h, cfg)), None
        m_body = jax.checkpoint(m_body) if cfg.remat else m_body
        h, _ = jax.lax.scan(m_body, h, gp["m"])
        if n_s:
            def s_body(h, sp):
                return L.shard_batch(ssm.slstm_block(sp, h, cfg)), None
            h, _ = jax.lax.scan(s_body, h, gp["s"])
        return h, None

    gp = {"m": params["m_blocks"]}
    if n_s:
        gp["s"] = params["s_blocks"]
    x, _ = jax.lax.scan(group, L.shard_batch(x), gp)
    return L.norm(params["ln_f"], x, cfg)


def loss_fn(params, batch, cfg):
    tokens, targets = batch["tokens"], batch["targets"]
    x = params["embed"][tokens].astype(_dt(cfg))
    h = backbone(params, x, cfg)
    logits = (h @ params["embed"].T.astype(h.dtype)).astype(jnp.float32)
    logits = L.shard_batch(logits, None, "model")
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# serving (O(1) state decode -> long_500k capable)
# ---------------------------------------------------------------------------


def init_cache(cfg, batch, max_seq, dtype=None):
    del max_seq  # state size is O(1) in sequence length
    dt = dtype or _dt(cfg)
    g, n_m, n_s = _groups(cfg)

    def stack(fn, outer, inner):
        one = fn()
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (outer, inner) + a.shape), one)

    cache = {"m": stack(lambda: ssm.mlstm_init_state(cfg, batch, dt), g, n_m),
             "pos": jnp.zeros((), jnp.int32)}
    if n_s:
        cache["s"] = stack(lambda: ssm.slstm_init_state(cfg, batch), g, n_s)
    return cache


def decode_step(params, cache, token, pos, cfg):
    g, n_m, n_s = _groups(cfg)
    x = params["embed"][token[:, 0]].astype(_dt(cfg))     # (B, D)

    def group(h, inp):
        gp, st = inp

        def m_body(h, ps):
            mp, mst = ps
            h, new = ssm.mlstm_decode(mp, mst, h, cfg)
            return h, new
        h, new_m = jax.lax.scan(m_body, h, (gp["m"], st["m"]))
        new = {"m": new_m}
        if n_s:
            def s_body(h, ps):
                sp, sst = ps
                h, ns = ssm.slstm_decode(sp, sst, h, cfg)
                return h, ns
            h, new_s = jax.lax.scan(s_body, h, (gp["s"], st["s"]))
            new["s"] = new_s
        return h, new

    gp = {"m": params["m_blocks"]}
    st = {"m": cache["m"]}
    if n_s:
        gp["s"] = params["s_blocks"]
        st["s"] = cache["s"]
    h, new_states = jax.lax.scan(group, x, (gp, st))
    h = L.rmsnorm(params["ln_f"], h[:, None, :], cfg.norm_eps)
    logits = h @ params["embed"].T.astype(h.dtype)
    new_states["pos"] = cache["pos"] + 1
    return logits, new_states


def prefill(params, batch, cfg):
    """Chunked forward over the prompt that also emits every block's final
    recurrent state (the chunked scan's inter-chunk carry), so decode continues
    exactly where the prompt left off."""
    g, n_m, n_s = _groups(cfg)
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(_dt(cfg))

    def group(h, gp):
        def m_body(h, mp):
            h, st = ssm.mlstm_block(mp, h, cfg, return_state=True)
            return L.shard_batch(h), st
        h, m_states = jax.lax.scan(m_body, h, gp["m"])
        out = {"m": m_states}
        if n_s:
            def s_body(h, sp):
                h, st = ssm.slstm_block(sp, h, cfg, return_state=True)
                return L.shard_batch(h), st
            h, s_states = jax.lax.scan(s_body, h, gp["s"])
            out["s"] = s_states
        return h, out

    gp = {"m": params["m_blocks"]}
    if n_s:
        gp["s"] = params["s_blocks"]
    h, states = jax.lax.scan(group, L.shard_batch(x), gp)
    h = L.norm(params["ln_f"], h, cfg)
    logits = h[:, -1:, :] @ params["embed"].T.astype(h.dtype)
    states["pos"] = jnp.asarray(tokens.shape[1], jnp.int32)
    return logits, states


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------


def param_specs(cfg, mode: str = "train"):
    policy = cfg.train_sharding if mode == "train" else cfg.serve_sharding
    fsdp = "data" if policy == "fsdp" else None
    g2 = (None, None)  # group, index-in-group

    def mb():
        return {
            "ln": {"scale": P(*g2, None)},
            "wq": P(*g2, fsdp, "model"), "wk": P(*g2, fsdp, "model"),
            "wv": P(*g2, fsdp, "model"), "wz": P(*g2, fsdp, "model"),
            "wif": P(*g2, fsdp, None),
            "norm": {"scale": P(*g2, None)},
            "wo": P(*g2, "model", fsdp),
            "conv": {"w": P(*g2, None, "model"), "b": P(*g2, "model")},
        }

    def sb():
        return {
            "ln": {"scale": P(*g2, None)},
            "w": P(*g2, fsdp, "model"),
            "r": P(*g2, None, None, None),
            "norm": {"scale": P(*g2, None)},
            "wo": P(*g2, "model", fsdp),
        }

    g, n_m, n_s = _groups(cfg)
    specs = {"embed": P("model", fsdp), "m_blocks": mb(),
             "ln_f": {"scale": P(None)}}
    if n_s:
        specs["s_blocks"] = sb()
    return specs


def cache_specs(cfg):
    g, n_m, n_s = _groups(cfg)
    # few heads (4) don't divide the model axis -> shard the per-head dim instead
    m = {"state": P(None, None, "data", None, "model", None),
         "conv": P(None, None, "data", None, "model")}
    specs = {"m": m, "pos": P()}
    if n_s:
        s = {k: P(None, None, "data", None, "model") for k in ("c", "n", "h")}
        specs["s"] = s
    return specs

from repro.models.api import get_model, Model  # noqa: F401

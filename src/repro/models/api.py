"""Model registry: a uniform functional interface over the zoo.

Every family exposes:
  init(key, cfg) -> params
  loss_fn(params, batch, cfg) -> scalar loss            (train path)
  prefill(params, batch, cfg) -> (logits, cache)        (decode-capable families)
  decode_step(params, cache, token, pos, cfg) -> (logits, cache)
  init_cache(cfg, batch, max_seq) -> cache
  param_specs(cfg, mode) / cache_specs(cfg) -> PartitionSpec pytrees
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

from repro.models import encdec, mlp, transformer, xlstm, zamba


@dataclass(frozen=True)
class Model:
    name: str
    init: Callable
    loss_fn: Callable
    param_specs: Callable
    prefill: Optional[Callable] = None
    decode_step: Optional[Callable] = None
    init_cache: Optional[Callable] = None
    cache_specs: Optional[Callable] = None

    @property
    def has_decode(self) -> bool:
        return self.decode_step is not None


def get_model(cfg) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        m = transformer
    elif fam == "ssm":
        m = xlstm
    elif fam == "hybrid":
        m = zamba
    elif fam == "audio":
        m = encdec
    elif fam == "mlp":
        return Model(name=cfg.name, init=mlp.zoo_init, loss_fn=mlp.zoo_loss_fn,
                     param_specs=mlp.param_specs)
    else:
        raise ValueError(f"unknown family {fam!r}")
    return Model(name=cfg.name, init=m.init, loss_fn=m.loss_fn,
                 param_specs=m.param_specs, prefill=m.prefill,
                 decode_step=m.decode_step, init_cache=m.init_cache,
                 cache_specs=m.cache_specs)

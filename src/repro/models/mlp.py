"""The paper's application model (§V): a two-layer network for L-class
classification — input P features, hidden J cells with swish activation
S(z) = z·sigmoid(z), softmax output, cross-entropy loss (eq. 28).

Parameters follow the paper exactly: ω0 = (ω_{0,l,j}) ∈ R^{L×J} output weights,
ω1 = (ω_{1,j,p}) ∈ R^{J×P} hidden weights — no biases.

The feature-based (vertical FL) helpers expose the paper's composition
structure f(ω;x) = g0(ω0, (h_{0,i}(ω_i, x_{n,i}))_i): client i holds the columns
ω1[:, P_i] and contributes the partial pre-activation h_i = z_i @ ω1[:,P_i].T;
the full hidden pre-activation is Σ_i h_i.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def swish(z):
    return z * jax.nn.sigmoid(z)


def init(key, num_features: int, hidden: int, num_classes: int, dtype=jnp.float32):
    k0, k1 = jax.random.split(key)
    return {
        "w0": (jax.random.normal(k0, (num_classes, hidden)) / jnp.sqrt(hidden)).astype(dtype),
        "w1": (jax.random.normal(k1, (hidden, num_features)) / jnp.sqrt(num_features)).astype(dtype),
    }


def logits(params, z):
    """z: (B, P) features -> (B, L) logits.  Q = softmax(w0 @ S(w1 z))."""
    pre = z @ params["w1"].T              # (B, J)
    return swish(pre) @ params["w0"].T    # (B, L)


def per_sample_loss(params, z, y):
    """Cross-entropy -Σ_l y_l log Q_l per sample. z: (B,P); y: (B,L) one-hot."""
    lg = logits(params, z).astype(jnp.float32)
    logq = jax.nn.log_softmax(lg, axis=-1)
    return -jnp.sum(y * logq, axis=-1)    # (B,)


def mean_loss(params, z, y):
    return jnp.mean(per_sample_loss(params, z, y))


def accuracy(params, z, labels):
    return jnp.mean(jnp.argmax(logits(params, z), axis=-1) == labels)


def l2_sq(params):
    return sum(jnp.sum(jnp.square(p)) for p in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# feature-based (vertical FL) composition structure
# ---------------------------------------------------------------------------


def feature_partition(num_features: int, num_clients: int) -> Sequence[jnp.ndarray]:
    """Contiguous partition of feature indices P into P_i, i=1..I."""
    sizes = [num_features // num_clients] * num_clients
    for i in range(num_features % num_clients):
        sizes[i] += 1
    idx, out = 0, []
    for s in sizes:
        out.append(jnp.arange(idx, idx + s))
        idx += s
    return out


def client_h(w1_block, z_block):
    """h_{0,i}(ω_i, x_{n,i}) = z_i @ ω1[:,P_i].T : (B, J) partial pre-activation."""
    return z_block @ w1_block.T


def logits_from_h(w0, h_sum):
    """g0 applied to the aggregated h: Q = softmax(w0 @ S(Σ_i h_i))."""
    return swish(h_sum) @ w0.T


def per_sample_loss_from_h(w0, h_sum, y):
    lg = logits_from_h(w0, h_sum).astype(jnp.float32)
    return -jnp.sum(y * jax.nn.log_softmax(lg, axis=-1), axis=-1)


# ---------------------------------------------------------------------------
# zoo integration (so the paper's own model also dry-runs / smokes)
# ---------------------------------------------------------------------------


def zoo_init(key, cfg):
    # reuse ModelConfig fields: d_ff=J hidden, vocab_size=L classes, d_model=P feats
    return init(key, cfg.d_model, cfg.d_ff, cfg.vocab_size, jnp.dtype(cfg.dtype))


def zoo_loss_fn(params, batch, cfg):
    return mean_loss(params, batch["features"], batch["labels_onehot"])


def param_specs(cfg, mode: str = "train"):
    return {"w0": P(None, "model"), "w1": P("model", None)}

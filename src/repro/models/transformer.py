"""Decoder-only transformer LM: dense (llama/gemma/qwen/glm style), MoE, and
VLM-backbone (prefix-LM over stubbed patch embeddings) variants.

Layer stack is scanned (stacked params, leading L axis) to keep HLO small enough
for 512-virtual-device dry-run compiles on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_layer(key, cfg):
    dt = _dt(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model, dt),
        "attn": L.attn_init(ks[0], cfg, dt),
        "ln2": L.rmsnorm_init(cfg.d_model, dt),
    }
    if cfg.n_experts:
        p["moe"] = L.moe_init(ks[1], cfg, dt)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.activation, dt)
    return p


def init(key, cfg):
    dt = _dt(cfg)
    k_embed, k_layers, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params = {
        "embed": L.embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dt),
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(layer_keys),
        "ln_f": L.rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.embed_init(k_out, (cfg.d_model, cfg.vocab_size), dt)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _block(lp, x, positions, cfg, mask):
    h = x + L.attention(lp["attn"], L.norm(lp["ln1"], x, cfg),
                        positions, cfg, mask=mask)
    y = L.norm(lp["ln2"], h, cfg)
    if cfg.n_experts:
        moe_fn = (L.moe_expert_parallel if cfg.moe_sharding == "expert_parallel"
                  else L.moe)
        m, aux = moe_fn(lp["moe"], y, cfg)
    else:
        m, aux = L.mlp(lp["mlp"], y, cfg.activation), jnp.float32(0)
    return h + m, aux


def backbone(params, x, positions, cfg, mask=None):
    """x: (B, S, D) embedded inputs -> (B, S, D) final-normed states, aux loss."""
    if mask is None and cfg.attention_impl != "chunked":
        mask = L.make_attention_mask(positions, positions, causal=True,
                                     window=cfg.sliding_window)
    # §Perf knob: sequence-parallel residual stream (psum -> reduce-scatter)
    seq_axis = "model" if cfg.seq_shard_activations else None

    def body(carry, lp):
        h, aux = carry
        h, a = _block(lp, h, positions, cfg, mask)
        h = L.shard_batch(h, seq_axis)   # keep clients (= data shards) resident
        return (h, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x = L.shard_batch(x)
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0)), params["layers"])
    return L.norm(params["ln_f"], x, cfg), aux


def embed(params, tokens, cfg):
    return params["embed"][tokens].astype(_dt(cfg)) * jnp.sqrt(float(cfg.d_model)).astype(_dt(cfg))


def logits_fn(params, h, cfg):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return h @ w.astype(h.dtype)


def _inputs_to_states(params, batch, cfg):
    """Handles plain LM and VLM prefix-LM inputs; returns (h, positions, mask,
    text_start) where loss applies from text_start onwards."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed(params, tokens, cfg)
    if cfg.num_prefix_tokens and "prefix_embeddings" in batch:
        pref = batch["prefix_embeddings"].astype(x.dtype)          # (B, Pfx, D)
        x = jnp.concatenate([pref, x], axis=1)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        mask = L.make_attention_mask(positions, positions, causal=True,
                                     window=cfg.sliding_window,
                                     prefix_len=pref.shape[1])
        return x, positions, mask, pref.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    return x, positions, None, 0


def loss_fn(params, batch, cfg):
    """Mean next-token cross-entropy (+ MoE aux). batch: tokens (B,S), targets (B,S)."""
    x, positions, mask, text_start = _inputs_to_states(params, batch, cfg)
    h, aux = backbone(params, x, positions, cfg, mask)
    h = h[:, text_start:, :]
    logits = logits_fn(params, h, cfg).astype(jnp.float32)
    logits = L.shard_batch(logits, None, "model")   # vocab over model axis
    targets = batch["targets"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    return nll + 0.01 * aux / max(1, cfg.n_layers)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_cache(cfg, batch, max_seq, dtype=None):
    dt = dtype or _dt(cfg)
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_seq, kv, hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def prefill(params, batch, cfg):
    """Full-sequence forward producing last-position logits and a filled cache."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x, positions, mask, _ = _inputs_to_states(params, batch, cfg)
    if mask is None and cfg.attention_impl != "chunked":
        mask = L.make_attention_mask(positions, positions, causal=True,
                                     window=cfg.sliding_window)

    def body(h, lp):
        hn = L.norm(lp["ln1"], h, cfg)
        q, k, v = L._qkv(lp["attn"], hn, cfg)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        rep = cfg.n_heads // cfg.n_kv_heads
        if cfg.attention_impl == "chunked":
            bq, sq = h.shape[0], h.shape[1]
            if rep > 1:
                kvh, hd = k.shape[2], k.shape[3]
                kf = jnp.broadcast_to(k[:, :, :, None, :],
                                      (bq, sq, kvh, rep, hd)).reshape(bq, sq, cfg.n_heads, hd)
                vf = jnp.broadcast_to(v[:, :, :, None, :],
                                      (bq, sq, kvh, rep, hd)).reshape(bq, sq, cfg.n_heads, hd)
            else:
                kf, vf = k, v
            o = L.chunked_attention(q, kf, vf, positions, positions, causal=True,
                                    window=cfg.sliding_window,
                                    block=cfg.attention_block)
        else:
            o = L.dot_attention(q, k, v, mask, kv_heads_repeat=rep)
        h = h + o.reshape(h.shape[0], h.shape[1], -1) @ lp["attn"]["wo"]
        y = L.norm(lp["ln2"], h, cfg)
        if cfg.n_experts:
            moe_fn = (L.moe_expert_parallel
                      if cfg.moe_sharding == "expert_parallel" else L.moe)
            m, _ = moe_fn(lp["moe"], y, cfg)
        else:
            m = L.mlp(lp["mlp"], y, cfg.activation)
        return L.shard_batch(h + m), (k, v)

    (h), kvs = jax.lax.scan(body, L.shard_batch(x), params["layers"])
    h = L.norm(params["ln_f"], h, cfg)
    logits = logits_fn(params, h[:, -1:, :], cfg)
    cache = {"k": kvs[0], "v": kvs[1]}
    return logits, cache


def decode_step(params, cache, token, pos, cfg):
    """One-token decode. token: (B, 1) int32; cache from init_cache/prefill."""
    x = embed(params, token, cfg)

    def body(h, inp):
        lp, ck, cv = inp
        hn = L.norm(lp["ln1"], h, cfg)
        o, ck, cv = L.attention_decode(lp["attn"], hn, ck, cv, pos, cfg,
                                       window=cfg.sliding_window)
        h = h + o
        y = L.norm(lp["ln2"], h, cfg)
        if cfg.n_experts:
            moe_fn = (L.moe_expert_parallel
                      if cfg.moe_sharding == "expert_parallel" else L.moe)
            m, _ = moe_fn(lp["moe"], y, cfg)
        else:
            m = L.mlp(lp["mlp"], y, cfg.activation)
        return h + m, (ck, cv)

    h, (ck, cv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    h = L.norm(params["ln_f"], h, cfg)
    logits = logits_fn(params, h, cfg)
    return logits, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# sharding specs
# ---------------------------------------------------------------------------


def param_specs(cfg, mode: str = "train"):
    """PartitionSpec pytree matching init(). mode: train (fsdp|tp) / serve (tp)."""
    policy = cfg.train_sharding if mode == "train" else cfg.serve_sharding
    fsdp = "data" if policy == "fsdp" else None
    kv_shardable = cfg.n_kv_heads % 16 == 0  # can kv-head dim split the model axis?

    attn = {
        "wq": P(None, fsdp, "model"),
        "wk": P(None, fsdp, "model" if kv_shardable else None),
        "wv": P(None, fsdp, "model" if kv_shardable else None),
        "wo": P(None, "model", fsdp),
    }
    if cfg.qkv_bias:
        attn.update({"bq": P(None, "model"),
                     "bk": P(None, "model" if kv_shardable else None),
                     "bv": P(None, "model" if kv_shardable else None)})
    lp = {"ln1": {"scale": P(None, None)}, "ln2": {"scale": P(None, None)}, "attn": attn}
    if cfg.n_experts:
        if cfg.moe_sharding == "expert_parallel":
            # experts resident on the model axis, replicated over data
            moe = {
                "router": P(None, None, None),
                "wi": P(None, "model", None, None),
                "wg": P(None, "model", None, None),
                "wo": P(None, "model", None, None),
            }
            if cfg.dense_residual:
                moe["dense"] = {"wi": P(None, None, "model"),
                                "wg": P(None, None, "model"),
                                "wo": P(None, "model", None)}
        elif cfg.moe_sharding == "expert2d":
            # §Perf: expert-parallel (model axis) x ffn-dim (data axis) 2D
            # sharding — weights stay resident, no per-step FSDP all-gathers
            moe = {
                "router": P(None, None, None),
                "wi": P(None, "model", None, "data"),
                "wg": P(None, "model", None, "data"),
                "wo": P(None, "model", "data", None),
            }
        else:
            moe = {
                "router": P(None, fsdp, None),
                "wi": P(None, "model", fsdp, None),
                "wg": P(None, "model", fsdp, None),
                "wo": P(None, "model", None, fsdp),
            }
        if cfg.dense_residual:
            moe["dense"] = {"wi": P(None, fsdp, "model"),
                            "wg": P(None, fsdp, "model"),
                            "wo": P(None, "model", fsdp)}
        lp["moe"] = moe
    else:
        lp["mlp"] = {"wi": P(None, fsdp, "model"),
                     "wg": P(None, fsdp, "model"),
                     "wo": P(None, "model", fsdp)}
        if cfg.activation == "gelu":
            del lp["mlp"]["wg"]
    specs = {"embed": P("model", fsdp), "layers": lp, "ln_f": {"scale": P(None)}}
    if not cfg.tie_embeddings:
        specs["unembed"] = P(fsdp, "model")
    return specs


def cache_specs(cfg):
    kv_shardable = cfg.n_kv_heads % 16 == 0
    # batch over data; kv-heads over model when divisible, else sequence over model
    if kv_shardable:
        spec = P(None, "data", None, "model", None)
    else:
        spec = P(None, "data", "model", None, None)
    return {"k": spec, "v": spec}

"""Encoder-decoder transformer (seamless-m4t style). The audio frontend
(mel-spectrogram + conv feature extractor) is stubbed per the brief:
``frame_embeddings`` (B, S_enc, D) arrive precomputed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def init_enc_layer(key, cfg):
    dt = _dt(cfg)
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, dt),
        "attn": L.attn_init(ks[0], cfg, dt),
        "ln2": L.rmsnorm_init(cfg.d_model, dt),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, "gelu", dt),
    }


def init_dec_layer(key, cfg):
    dt = _dt(cfg)
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, dt),
        "self_attn": L.attn_init(ks[0], cfg, dt),
        "ln_x": L.rmsnorm_init(cfg.d_model, dt),
        "cross_attn": L.attn_init(ks[1], cfg, dt),
        "ln2": L.rmsnorm_init(cfg.d_model, dt),
        "mlp": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, "gelu", dt),
    }


def init(key, cfg):
    dt = _dt(cfg)
    k_e, k_enc, k_dec = jax.random.split(key, 3)
    return {
        "embed": L.embed_init(k_e, (cfg.vocab_size, cfg.d_model), dt),
        "encoder": jax.vmap(lambda k: init_enc_layer(k, cfg))(
            jax.random.split(k_enc, cfg.encoder_layers)),
        "decoder": jax.vmap(lambda k: init_dec_layer(k, cfg))(
            jax.random.split(k_dec, cfg.n_layers)),
        "ln_enc": L.rmsnorm_init(cfg.d_model, dt),
        "ln_f": L.rmsnorm_init(cfg.d_model, dt),
    }


def encode(params, frames, cfg):
    """frames: (B, S_enc, D) stubbed frontend embeddings -> encoder states."""
    b, s, _ = frames.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    mask = jnp.ones((1, s, s), bool)

    def body(h, lp):
        h = h + L.attention(lp["attn"], L.norm(lp["ln1"], h, cfg),
                            positions, cfg, mask=mask)
        h = h + L.mlp(lp["mlp"], L.norm(lp["ln2"], h, cfg), "gelu")
        return L.shard_batch(h), None

    body = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body, L.shard_batch(frames.astype(_dt(cfg))), params["encoder"])
    return L.norm(params["ln_enc"], h, cfg)


def _cross_kv(lp, enc, cfg):
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    b, s, _ = enc.shape
    k = (enc @ lp["wk"]).reshape(b, s, kv, hd)
    v = (enc @ lp["wv"]).reshape(b, s, kv, hd)
    return k, v


def decode_stack(params, x, enc, positions, cfg, return_cache: bool = False):
    b, s, _ = x.shape
    self_mask = L.make_attention_mask(positions, positions, causal=True)

    def body(h, lp):
        hn = L.norm(lp["ln1"], h, cfg)
        q, k, v = L._qkv(lp["self_attn"], hn, cfg)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        o = L.dot_attention(q, k, v, self_mask,
                            kv_heads_repeat=cfg.n_heads // cfg.n_kv_heads)
        h = h + o.reshape(b, s, -1) @ lp["self_attn"]["wo"]
        hx = L.norm(lp["ln_x"], h, cfg)
        ck, cv = _cross_kv(lp["cross_attn"], enc, cfg)
        qx = (hx @ lp["cross_attn"]["wq"]).reshape(b, s, cfg.n_heads,
                                                   cfg.resolved_head_dim)
        cm = jnp.ones((1, s, enc.shape[1]), bool)
        o = L.dot_attention(qx, ck, cv, cm,
                            kv_heads_repeat=cfg.n_heads // cfg.n_kv_heads)
        h = h + o.reshape(b, s, -1) @ lp["cross_attn"]["wo"]
        h = h + L.mlp(lp["mlp"], L.norm(lp["ln2"], h, cfg), "gelu")
        return L.shard_batch(h), ((k, v) if return_cache else None)

    if not return_cache and cfg.remat:
        body = jax.checkpoint(body)
    h, kvs = jax.lax.scan(body, L.shard_batch(x), params["decoder"])
    h = L.norm(params["ln_f"], h, cfg)
    return (h, kvs) if return_cache else h


def loss_fn(params, batch, cfg):
    enc = encode(params, batch["frame_embeddings"], cfg)
    tokens, targets = batch["tokens"], batch["targets"]
    b, s = tokens.shape
    x = params["embed"][tokens].astype(_dt(cfg))
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    h = decode_stack(params, x, enc, positions, cfg)
    logits = (h @ params["embed"].T.astype(h.dtype)).astype(jnp.float32)
    logits = L.shard_batch(logits, None, "model")
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# serving: self-attn KV cache + precomputed per-layer cross K/V
# ---------------------------------------------------------------------------


def init_cache(cfg, batch, max_seq, dtype=None, enc_len=None):
    dt = dtype or _dt(cfg)
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    enc_len = enc_len or max_seq
    dec_len = min(max_seq, 4096)
    return {
        "self_k": jnp.zeros((cfg.n_layers, batch, dec_len, kv, hd), dt),
        "self_v": jnp.zeros((cfg.n_layers, batch, dec_len, kv, hd), dt),
        "cross_k": jnp.zeros((cfg.n_layers, batch, enc_len, kv, hd), dt),
        "cross_v": jnp.zeros((cfg.n_layers, batch, enc_len, kv, hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, batch, cfg):
    enc = encode(params, batch["frame_embeddings"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens].astype(_dt(cfg))
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    h, (sk, sv) = decode_stack(params, x, enc, positions, cfg, return_cache=True)
    logits = h[:, -1:, :] @ params["embed"].T.astype(h.dtype)

    def kv_body(_, lp):
        return None, _cross_kv(lp["cross_attn"], enc, cfg)
    _, (ck, cv) = jax.lax.scan(kv_body, None, params["decoder"])
    cache = {"self_k": sk, "self_v": sv, "cross_k": ck, "cross_v": cv,
             "pos": jnp.asarray(s, jnp.int32)}
    return logits, cache


def decode_step(params, cache, token, pos, cfg):
    b = token.shape[0]
    x = params["embed"][token].astype(_dt(cfg))                  # (B,1,D)

    def body(h, inp):
        lp, sk, sv, ck, cv = inp
        hn = L.norm(lp["ln1"], h, cfg)
        o, sk, sv = L.attention_decode(lp["self_attn"], hn, sk, sv, pos, cfg)
        h = h + o
        hx = L.norm(lp["ln_x"], h, cfg)
        q = (hx @ lp["cross_attn"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.resolved_head_dim)
        cm = jnp.ones((1, 1, ck.shape[1]), bool)
        o = L.dot_attention(q, ck.astype(q.dtype), cv.astype(q.dtype), cm,
                            kv_heads_repeat=cfg.n_heads // cfg.n_kv_heads)
        h = h + o.reshape(b, 1, -1) @ lp["cross_attn"]["wo"]
        h = h + L.mlp(lp["mlp"], L.norm(lp["ln2"], h, cfg), "gelu")
        return h, (sk, sv)

    h, (sk, sv) = jax.lax.scan(
        body, x, (params["decoder"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    h = L.norm(params["ln_f"], h, cfg)
    logits = h @ params["embed"].T.astype(h.dtype)
    new_cache = dict(cache, self_k=sk, self_v=sv, pos=cache["pos"] + 1)
    return logits, new_cache


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------


def param_specs(cfg, mode: str = "train"):
    policy = cfg.train_sharding if mode == "train" else cfg.serve_sharding
    fsdp = "data" if policy == "fsdp" else None
    kv_shardable = cfg.n_kv_heads % 16 == 0

    def attn():
        return {"wq": P(None, fsdp, "model"),
                "wk": P(None, fsdp, "model" if kv_shardable else None),
                "wv": P(None, fsdp, "model" if kv_shardable else None),
                "wo": P(None, "model", fsdp)}

    mlp_s = {"wi": P(None, fsdp, "model"), "wo": P(None, "model", fsdp)}
    enc = {"ln1": {"scale": P(None, None)}, "attn": attn(),
           "ln2": {"scale": P(None, None)}, "mlp": mlp_s}
    dec = {"ln1": {"scale": P(None, None)}, "self_attn": attn(),
           "ln_x": {"scale": P(None, None)}, "cross_attn": attn(),
           "ln2": {"scale": P(None, None)}, "mlp": dict(mlp_s)}
    return {"embed": P("model", fsdp), "encoder": enc, "decoder": dec,
            "ln_enc": {"scale": P(None)}, "ln_f": {"scale": P(None)}}


def cache_specs(cfg):
    kv_shardable = cfg.n_kv_heads % 16 == 0
    spec = (P(None, "data", None, "model", None) if kv_shardable
            else P(None, "data", "model", None, None))
    return {"self_k": spec, "self_v": spec, "cross_k": spec, "cross_v": spec,
            "pos": P()}

"""Shared neural-net layers for the model zoo (pure-function style, dict pytrees).

Conventions:
  - activations:  (B, S, D) ; attention heads laid out (B, S, H, Hd)
  - stacked layer params carry a leading L axis and are consumed by lax.scan
  - params are created in ``param_dtype`` and computation runs in ``dtype``
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# ambient-mesh activation sharding
# ---------------------------------------------------------------------------


def _ambient_mesh():
    try:
        from jax._src import mesh as _mesh_lib
        m = _mesh_lib.thread_resources.env.physical_mesh
        return m if m.axis_names else None
    except Exception:
        return None


def _ambient_axes():
    """Axis names of the mesh in context (legacy `with mesh:` or none)."""
    m = _ambient_mesh()
    return tuple(m.axis_names) if m is not None else ()


def model_axis_divides(n: int) -> bool:
    """True iff the ambient mesh has a 'model' axis whose size divides n."""
    m = _ambient_mesh()
    if m is None or "model" not in m.axis_names:
        return False
    return n % m.shape["model"] == 0


def shard_spec(x, entries):
    """with_sharding_constraint with raw entries; no-op outside a mesh."""
    axes = _ambient_axes()
    if not axes:
        return x
    fixed = []
    for e in entries:
        if e == "batch":
            fixed.append(tuple(a for a in ("pod", "data") if a in axes) or None)
        elif e is None or e in axes:
            fixed.append(e)
        else:
            fixed.append(None)
    while len(fixed) < x.ndim:
        fixed.append(None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*fixed))
    except (RuntimeError, ValueError):
        return x


def shard_batch(x, *rest):
    """Constrain activation sharding: dim0 = batch over the data axes of the
    ambient mesh ('pod','data'), remaining dims per `rest` entries (axis names
    filtered against the mesh). No-op outside a mesh context (smoke tests).

    This is not just a perf knob: batch-sharding the activations IS the
    paper's client partitioning (clients = data shards) — XLA must never
    gather per-client activations to a single shard.
    """
    axes = _ambient_axes()
    if not axes:
        return x
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    if not batch_axes:
        return x
    entries = [batch_axes]
    for r in rest:
        entries.append(r if (r is None or r in axes) else None)
    while len(entries) < x.ndim:
        entries.append(None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*entries))
    except (RuntimeError, ValueError):
        return x


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d, dtype):
    return {"scale": jnp.zeros((d,), dtype)}   # gemma-style (1 + scale)


def rmsnorm(params, x, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


# --- fused-backward variant (§Perf): identical math, but the VJP keeps all
# (B,S,D)-sized tensors in the input dtype — only per-row statistics are fp32.
# The autodiff of the reference materializes several fp32 residual-stream
# tensors per norm per direction (measured: the dominant memory-term item on
# deepseek-67b/qwen train; EXPERIMENTS.md §Perf).


import functools as _ft


@_ft.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rms_fused(x, scale, eps):
    return rmsnorm({"scale": scale}, x, eps)


def _rms_fused_fwd(x, scale, eps):
    x32 = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(jnp.square(x32), axis=-1, keepdims=True) + eps)
    y = (x32 * r * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)
    return y, (x, scale, r)


def _rms_fused_bwd(eps, res, dy):
    x, scale, r = res
    d = x.shape[-1]
    g1 = (1.0 + scale.astype(jnp.float32)).astype(x.dtype)
    rd = r.astype(x.dtype)                                  # (.., 1) broadcast
    t = x * (dy * g1)                                        # elementwise, x.dtype
    s1 = jnp.sum(t.astype(jnp.float32), axis=-1, keepdims=True)   # fp32 rows
    dx = (dy * g1) * rd - x * ((r ** 3) * (s1 / d)).astype(x.dtype)
    dscale = jnp.sum((x * dy).astype(jnp.float32) * r,
                     axis=tuple(range(x.ndim - 1)))
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


_rms_fused.defvjp(_rms_fused_fwd, _rms_fused_bwd)


def norm(params, x, cfg):
    """RMSNorm dispatcher: cfg.norm_impl selects ref vs fused-backward."""
    if getattr(cfg, "norm_impl", "ref") == "fused":
        return _rms_fused(x, params["scale"], cfg.norm_eps)
    return rmsnorm(params, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """x: (..., S, H, Hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq          # (..., S, half)
    ang = ang[..., None, :]                                        # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA, causal, sliding-window, prefix-LM, cross, decode)
# ---------------------------------------------------------------------------


def attn_init(key, cfg, dtype, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype, fan_in=d),
        "wk": dense_init(ks[1], (d, kv * hd), dtype, fan_in=d),
        "wv": dense_init(ks[2], (d, kv * hd), dtype, fan_in=d),
        "wo": dense_init(ks[3], (h * hd, d), dtype, fan_in=h * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def _qkv(params, x, cfg):
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    b, s, _ = x.shape
    return (q.reshape(b, s, h, hd), k.reshape(b, s, kv, hd), v.reshape(b, s, kv, hd))


def make_attention_mask(q_pos, k_pos, *, causal=True, window=0, prefix_len=0):
    """(..., Sq, Sk) boolean mask. prefix positions attend bidirectionally."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        m = kp <= qp
    else:
        m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if window:
        m = m & (qp - kp < window)
    if prefix_len:
        m = m | (kp < prefix_len)
    return m


def dot_attention(q, k, v, mask, *, kv_heads_repeat: int):
    """q:(B,Sq,H,Hd) k,v:(B,Sk,KV,Hd) mask:(B|1,Sq,Sk) -> (B,Sq,H,Hd).

    GQA is handled by broadcasting K/V to H heads (a local view — KV is
    replicated or head-sharded consistently, so no collective is induced).
    Sharding: heads over the 'model' axis when H divides it; otherwise the
    query-sequence dim is model-sharded (sequence-parallel attention; softmax
    is over the K dim, which stays local either way).
    """
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    if kv_heads_repeat > 1:
        k = jnp.broadcast_to(k[:, :, :, None, :],
                             (b, sk, kvh, kv_heads_repeat, hd)).reshape(b, sk, h, hd)
        v = jnp.broadcast_to(v[:, :, :, None, :],
                             (b, sk, kvh, kv_heads_repeat, hd)).reshape(b, sk, h, hd)
    hdiv = model_axis_divides(h)
    q = shard_spec(q, ["batch", None, "model", None] if hdiv
                   else ["batch", "model", None, None])
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32)
    logits = logits / math.sqrt(hd)
    logits = shard_spec(logits, ["batch", "model", None, None] if hdiv
                        else ["batch", None, "model", None])
    logits = jnp.where(mask[:, None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", w, v)
    return shard_spec(out, ["batch", None, "model", None] if hdiv
                      else ["batch", "model", None, None])


def _cattn_mask(qp, kpp, causal, window, prefix_len, sq, blk):
    ok = jnp.ones((1, sq, blk), bool)
    if causal:
        ok &= kpp <= qp
    if window:
        ok &= qp - kpp < window
    if prefix_len:
        ok |= kpp < prefix_len
    ok &= kpp < 2**30                                           # padding
    return ok


def _cattn_fwd_scan(qt, kb, vb, kp, qp, scale, causal, window, prefix_len):
    b, h, sq, hd = qt.shape
    blk = kb.shape[3]

    def step(carry, inp):
        m, l, acc = carry
        kblk, vblk, kpb = inp
        s = jnp.einsum("bhqd,bhkd->bhqk", qt, kblk) * scale
        ok = _cattn_mask(qp, kpb[:, None, :], causal, window, prefix_len, sq, blk)
        s = jnp.where(ok[:, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(ok[:, None], jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vblk)
        return (m_new, l, acc), None

    m0 = jnp.full((b, h, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, kp))
    lse = m + jnp.log(jnp.where(l == 0.0, 1.0, l))              # logsumexp rows
    out = acc / jnp.where(l == 0.0, 1.0, l)[..., None]
    return out, lse


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _cattn(qt, kb, vb, kp, qp, causal, window, prefix_len):
    """Flash-attention forward (blocked online softmax). The custom VJP
    recomputes p blockwise in the backward pass (standard flash backward) —
    nothing O(Sq·Sk) is ever saved, unlike grad-of-scan which stashes every
    per-block tensor (measured +63% HBM traffic on deepseek-67b; §Perf log).

    qt: (B,H,Sq,Hd) f32; kb,vb: (N,B,H,blk,Hd) f32; kp: (N,1,blk); qp: (1,Sq,1).
    """
    hd = qt.shape[-1]
    out, _ = _cattn_fwd_scan(qt, kb, vb, kp, qp, 1.0 / math.sqrt(hd),
                             causal, window, prefix_len)
    return out


def _cattn_fwd(qt, kb, vb, kp, qp, causal, window, prefix_len):
    hd = qt.shape[-1]
    out, lse = _cattn_fwd_scan(qt, kb, vb, kp, qp, 1.0 / math.sqrt(hd),
                               causal, window, prefix_len)
    return out, (qt, kb, vb, kp, qp, out, lse)


def _cattn_bwd(causal, window, prefix_len, res, dout):
    qt, kb, vb, kp, qp, out, lse = res
    b, h, sq, hd = qt.shape
    blk = kb.shape[3]
    scale = 1.0 / math.sqrt(hd)
    delta = jnp.sum(dout * out, axis=-1)                        # (B,H,Sq)

    def step(dq, inp):
        kblk, vblk, kpb = inp
        s = jnp.einsum("bhqd,bhkd->bhqk", qt, kblk) * scale
        ok = _cattn_mask(qp, kpb[:, None, :], causal, window, prefix_len, sq, blk)
        p = jnp.where(ok[:, None], jnp.exp(s - lse[..., None]), 0.0)
        dv = jnp.einsum("bhqk,bhqd->bhkd", p, dout)
        dp = jnp.einsum("bhqd,bhkd->bhqk", dout, vblk)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds, kblk)
        dk = jnp.einsum("bhqk,bhqd->bhkd", ds, qt)
        return dq, (dk, dv)

    dq0 = jnp.zeros_like(qt)
    dq, (dk, dv) = jax.lax.scan(step, dq0, (kb, vb, kp))
    return dq, dk, dv, None, None


_cattn.defvjp(_cattn_fwd, _cattn_bwd)


def chunked_attention(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                      prefix_len=0, block=512):
    """Flash-attention algorithm at the XLA level: blocked online softmax with
    a recompute-based custom VJP. Never materializes the (Sq, Sk) logits —
    working set is (Sq, block). This is the jnp mirror of
    kernels/flash_attention.py (which replaces it on real TPU).

    q: (B,Sq,H,Hd); k,v: (B,Sk,H,Hd) (already GQA-broadcast);
    q_pos/k_pos: (1, Sq)/(1, Sk). Returns (B,Sq,H,Hd).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    blk = min(block, sk)
    pad = (-sk) % blk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=2**30)
    n = k.shape[1] // blk
    hdiv = model_axis_divides(h)
    qspec = ["batch", None, "model", None] if hdiv else ["batch", "model", None, None]
    q = shard_spec(q, qspec)
    qt = q.transpose(0, 2, 1, 3).astype(jnp.float32)            # (B,H,Sq,Hd)
    kb = k.reshape(b, n, blk, h, hd).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    vb = v.reshape(b, n, blk, h, hd).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    kp = k_pos.reshape(1, n, blk).transpose(1, 0, 2)            # (N,1,blk)
    qp = q_pos[..., :, None]                                    # (1,Sq,1)
    out = _cattn(qt, kb, vb, kp, qp, causal, window, prefix_len)
    out = out.transpose(0, 2, 1, 3).astype(v.dtype)
    return shard_spec(out, qspec)


def attention(params, x, positions, cfg, *, mask=None, kv_override=None):
    """Full (training/prefill) attention. kv_override: (k, v) for cross-attention."""
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _qkv(params, x, cfg)
    if kv_override is not None:
        k, v = kv_override
    else:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    b, s = x.shape[:2]
    if getattr(cfg, "attention_impl", "dot") == "chunked" and kv_override is None:
        rep = h // k.shape[2]
        if rep > 1:
            sk, kvh = k.shape[1], k.shape[2]
            k = jnp.broadcast_to(k[:, :, :, None, :],
                                 (b, sk, kvh, rep, hd)).reshape(b, sk, h, hd)
            v = jnp.broadcast_to(v[:, :, :, None, :],
                                 (b, sk, kvh, rep, hd)).reshape(b, sk, h, hd)
        out = chunked_attention(q, k, v, positions, positions, causal=True,
                                window=cfg.sliding_window,
                                prefix_len=getattr(cfg, "_prefix_len", 0),
                                block=cfg.attention_block)
    else:
        if mask is None:
            mask = make_attention_mask(positions, positions, causal=True,
                                       window=cfg.sliding_window)
        out = dot_attention(q, k, v, mask, kv_heads_repeat=h // k.shape[2])
    return out.reshape(b, s, h * hd) @ params["wo"]


def attention_decode(params, x, cache_k, cache_v, pos, cfg, *, window=0):
    """One-token decode against a preallocated KV cache.

    x: (B, 1, D); cache_k/v: (B, S_max, KV, Hd); pos: scalar int32 (current index).
    Returns (out, new_cache_k, new_cache_v).
    """
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b = x.shape[0]
    q, k, v = _qkv(params, x, cfg)
    p1 = jnp.full((b, 1), pos, jnp.int32)
    q = rope(q, p1, cfg.rope_theta)
    k = rope(k, p1, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
    s_max = cache_k.shape[1]
    k_pos = jnp.arange(s_max, dtype=jnp.int32)[None, :]
    mask = k_pos <= pos
    if window:
        mask = mask & (pos - k_pos < window)
    mask = mask[:, None, :]                      # (1, 1, S_max), broadcast
    out = dot_attention(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype), mask,
                        kv_heads_repeat=h // kv)
    out = out.reshape(b, 1, h * hd) @ params["wo"]
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP blocks
# ---------------------------------------------------------------------------


def mlp_init(key, d, d_ff, activation, dtype):
    ks = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {
            "wi": dense_init(ks[0], (d, d_ff), dtype, fan_in=d),
            "wg": dense_init(ks[1], (d, d_ff), dtype, fan_in=d),
            "wo": dense_init(ks[2], (d_ff, d), dtype, fan_in=d_ff),
        }
    return {
        "wi": dense_init(ks[0], (d, d_ff), dtype, fan_in=d),
        "wo": dense_init(ks[2], (d_ff, d), dtype, fan_in=d_ff),
    }


def mlp(params, x, activation: str):
    if activation == "swiglu":
        return (jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])) @ params["wo"]
    if activation == "geglu":
        return (jax.nn.gelu(x @ params["wg"], approximate=True) * (x @ params["wi"])) @ params["wo"]
    return jax.nn.gelu(x @ params["wi"], approximate=True) @ params["wo"]


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k router, scatter dispatch, expert-parallel friendly)
# ---------------------------------------------------------------------------


def moe_init(key, cfg, dtype):
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e), dtype, fan_in=d),
        "wi": dense_init(ks[1], (e, d, ff), dtype, fan_in=d),
        "wg": dense_init(ks[2], (e, d, ff), dtype, fan_in=d),
        "wo": dense_init(ks[3], (e, ff, d), dtype, fan_in=ff),
    }
    if cfg.dense_residual:
        p["dense"] = mlp_init(jax.random.fold_in(key, 7), d, cfg.d_ff, "swiglu", dtype)
    return p


def moe(params, x, cfg):
    """Top-k MoE with fixed per-expert capacity and scatter dispatch.

    x: (B, S, D). Returns (out, aux_loss). Dispatch uses scatter-add (no dense
    one-hot einsum) so compiled FLOPs stay ~= active-expert FLOPs.
    """
    b, s, d = x.shape
    e, k, ff = cfg.n_experts, cfg.experts_per_token, cfg.moe_d_ff
    t = b * s
    xt = x.reshape(t, d)
    logits = (xt @ params["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                        # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    cap = max(1, int(cfg.capacity_factor * t * k / e))
    flat_e = top_e.reshape(-1)                                    # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)           # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) * onehot                     # 1-based slot
    slot = jnp.sum(pos, axis=-1) - 1                              # (T*k,)
    keep = slot < cap
    slot = jnp.where(keep, slot, cap - 1)

    buf = jnp.zeros((e, cap, d), xt.dtype)
    src = jnp.repeat(xt, k, axis=0) * keep[:, None].astype(xt.dtype)
    buf = buf.at[flat_e, slot].add(src)                           # dispatch
    buf = shard_spec(buf, ["model", None, None])                  # expert parallel

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["wg"])) * \
        jnp.einsum("ecd,edf->ecf", buf, params["wi"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["wo"])         # (E, cap, D)

    gathered = out_buf[flat_e, slot]                              # (T*k, D)
    gathered = gathered * (keep[:, None] * top_p.reshape(-1)[:, None]).astype(xt.dtype)
    out = jnp.sum(gathered.reshape(t, k, d), axis=1)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)

    if cfg.dense_residual:
        out = out + mlp(params["dense"], xt, "swiglu")
    return out.reshape(b, s, d), aux


def moe_expert_parallel(params, x, cfg):
    """Expert-parallel MoE via shard_map (§Perf iteration 5's proper fix).

    Tokens are data-sharded and *replicated over the model axis*; experts are
    model-sharded. Each (data, model) shard therefore already holds every
    token it needs: it dispatches its local tokens to its local experts and
    the combine is a single psum over "model" — the 750 GB/chip dispatch
    all-gather GSPMD emits for the global scatter (EXPERIMENTS.md §Perf #5)
    disappears entirely; the remaining collective is one (B,S,d) psum per
    layer, the same shape a dense FFN partial-sum costs.

    Requires expert weights to fit per chip at E/M (true for qwen3-moe's
    768-wide experts; arctic-480b needs the 2-D expert2d layout instead).
    Falls back to the GSPMD path outside a mesh (smoke tests).
    """
    mesh = _ambient_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return moe(params, x, cfg)
    from jax.experimental.shard_map import shard_map

    m_size = mesh.shape["model"]
    if cfg.n_experts % m_size:
        return moe(params, x, cfg)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    e_loc = cfg.n_experts // m_size

    def local(router, wi, wg, wo, dense, xl):
        b, s, d = xl.shape
        e, k, ff = cfg.n_experts, cfg.experts_per_token, cfg.moe_d_ff
        t = b * s
        xt = xl.reshape(t, d)
        logits = (xt @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

        off = jax.lax.axis_index("model") * e_loc
        flat_e = top_e.reshape(-1) - off                     # local expert ids
        mine = (flat_e >= 0) & (flat_e < e_loc)
        flat_e = jnp.clip(flat_e, 0, e_loc - 1)
        cap = max(1, int(cfg.capacity_factor * t * k / e))
        onehot = jax.nn.one_hot(flat_e, e_loc, dtype=jnp.int32) \
            * mine[:, None].astype(jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) * onehot
        slot = jnp.sum(pos, axis=-1) - 1
        keep = mine & (slot >= 0) & (slot < cap)
        slot = jnp.clip(slot, 0, cap - 1)

        buf = jnp.zeros((e_loc, cap, d), xt.dtype)
        src = jnp.repeat(xt, k, axis=0) * keep[:, None].astype(xt.dtype)
        buf = buf.at[flat_e, slot].add(src)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
            jnp.einsum("ecd,edf->ecf", buf, wi)
        out_buf = jnp.einsum("ecf,efd->ecd", h, wo)
        gathered = out_buf[flat_e, slot]
        gathered = gathered * (keep[:, None] * top_p.reshape(-1)[:, None]
                               ).astype(xt.dtype)
        out = jnp.sum(gathered.reshape(t, k, d), axis=1)
        out = jax.lax.psum(out, "model")                     # the combine

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), axis=0)
        aux = e * jnp.sum(me * ce)
        if cfg.dense_residual:
            out = out + mlp(dense, xt, "swiglu")
        return out.reshape(b, s, d), aux

    pspec = P(*([batch_axes] if batch_axes else [None]), None, None)
    dense = params.get("dense")
    dense_spec = (jax.tree.map(lambda _: P(None, None), dense)
                  if dense is not None else None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(None, None), P("model", None, None), P("model", None, None),
                  P("model", None, None), dense_spec, pspec),
        out_specs=(pspec, P()),
        check_rep=False)
    return fn(params["router"], params["wi"], params["wg"], params["wo"],
              dense, x)

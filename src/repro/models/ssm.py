"""Sub-quadratic sequence mixers: chunked gated linear attention (the SSD /
mamba2 dual form), mamba2 blocks, and xLSTM (mLSTM + sLSTM) blocks.

All train-time paths are chunked (O(S·C + S·d·N) not O(S^2)); decode paths are
O(1)-state recurrent updates, which is what makes ``long_500k`` runnable.

Adaptations vs. the source papers (recorded in DESIGN.md):
  - mLSTM input gate uses sigmoid (bounded) instead of exp+stabilizer; the
    linear-attention structure and denominator normalization are preserved.
  - mamba2 uses n_groups=1 (B/C shared across heads), scalar-per-head decay.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L


# ---------------------------------------------------------------------------
# chunked gated linear attention
#   H_t = a_t * H_{t-1} + k_t^T v_t ;  y_t = q_t @ H_t
# ---------------------------------------------------------------------------


def chunked_gla(q, k, v, log_a, chunk: int, initial_state=None):
    """q,k: (B,H,S,Dk)  v: (B,H,S,Dv)  log_a: (B,H,S) with log_a <= 0.

    Returns (y: (B,H,S,Dv), final_state: (B,H,Dk,Dv)).
    """
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    pad = (-s) % c
    if pad:  # pad tail (causal: padding only affects its own sliced-off outputs)
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, 0), (0, pad)))
        s_orig, s = s, s + pad
    else:
        s_orig = s
    n = s // c
    f32 = jnp.float32
    qc = q.reshape(b, h, n, c, dk).astype(f32)
    kc = k.reshape(b, h, n, c, dk).astype(f32)
    vc = v.reshape(b, h, n, c, dv).astype(f32)
    la = jnp.cumsum(log_a.reshape(b, h, n, c).astype(f32), axis=-1)   # within-chunk cum
    la_end = la[..., -1:]                                             # (B,H,N,1)

    # ---- intra-chunk (strictly causal incl. diagonal) ----
    # score_ij = (q_i . k_j) * exp(la_i - la_j), j <= i  (la_i - la_j <= 0)
    gap = la[..., :, None] - la[..., None, :]                         # (B,H,N,C,C)
    causal = jnp.tril(jnp.ones((c, c), bool))
    decay = jnp.where(causal, jnp.exp(jnp.minimum(gap, 0.0)), 0.0)    # exp only where causal
    w = jnp.einsum("bhncd,bhnkd->bhnck", qc, kc) * decay
    y_intra = jnp.einsum("bhnck,bhnkv->bhncv", w, vc)

    # ---- inter-chunk state recurrence ----
    kd = kc * jnp.exp(la_end - la)[..., None]                         # decay to chunk end
    s_chunk = jnp.einsum("bhnck,bhncv->bhnkv", kd, vc)                # (B,H,N,Dk,Dv)
    a_chunk = jnp.exp(la_end[..., 0])                                 # (B,H,N)

    def step(hstate, inp):
        s_c, a_c = inp
        h_prev = hstate
        hstate = a_c[..., None, None] * hstate + s_c
        return hstate, h_prev

    init = (jnp.zeros((b, h, dk, dv), f32) if initial_state is None
            else initial_state.astype(f32))
    # scan over chunk axis (move N to front)
    s_chunk_t = jnp.moveaxis(s_chunk, 2, 0)
    a_chunk_t = jnp.moveaxis(a_chunk, 2, 0)
    final_state, h_prevs = jax.lax.scan(step, init, (s_chunk_t, a_chunk_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 2)                             # (B,H,N,Dk,Dv)

    y_inter = jnp.einsum("bhncd,bhndv->bhncv", qc * jnp.exp(la)[..., None], h_prevs)
    y = (y_intra + y_inter).reshape(b, h, s, dv)[:, :, :s_orig, :]
    return y.astype(v.dtype), final_state


def gla_decode_step(state, q, k, v, log_a):
    """One-step recurrence. state: (B,H,Dk,Dv); q,k: (B,H,Dk); v: (B,H,Dv);
    log_a: (B,H). Returns (y: (B,H,Dv), new_state)."""
    f32 = jnp.float32
    a = jnp.exp(log_a.astype(f32))[..., None, None]
    new = a * state.astype(f32) + k.astype(f32)[..., :, None] * v.astype(f32)[..., None, :]
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(f32), new)
    return y.astype(v.dtype), new


# ---------------------------------------------------------------------------
# mamba2 block
# ---------------------------------------------------------------------------


def _conv1d_init(key, width, channels, dtype):
    return {"w": L.dense_init(key, (width, channels), dtype, fan_in=width),
            "b": jnp.zeros((channels,), dtype)}


def _causal_conv(p, x):
    """x: (B, S, C) depthwise causal conv, width W."""
    w = p["w"]                                  # (W, C)
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(width))
    return out + p["b"]


def _conv_decode(p, buf, x):
    """buf: (B, W-1, C) previous inputs; x: (B, C). Returns (y, new_buf)."""
    w = p["w"]
    window = jnp.concatenate([buf, x[:, None, :]], axis=1)            # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", window, w) + p["b"]
    return y, window[:, 1:, :]


def mamba2_init(key, cfg, dtype):
    d = cfg.d_model
    e = cfg.ssm_expand
    di = e * d                        # inner dim
    h = cfg.ssm_heads
    n = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "ln": L.rmsnorm_init(d, dtype),
        # fused in-proj: [x(di), z(di), B(n), C(n), dt(h)]
        "w_in": L.dense_init(ks[0], (d, 2 * di + 2 * n + h), dtype, fan_in=d),
        "conv": _conv1d_init(ks[1], cfg.conv_width, di + 2 * n, dtype),
        "a_log": jnp.zeros((h,), jnp.float32),
        "dt_bias": jnp.full((h,), math.log(math.e - 1), jnp.float32),  # softplus^-1(1)
        "norm": L.rmsnorm_init(di, dtype),
        "w_out": L.dense_init(ks[2], (di, d), dtype, fan_in=di),
    }


def _mamba2_proj(p, x, cfg):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = cfg.ssm_heads
    z = x @ p["w_in"]
    xs, zgate, bmat, cmat, dt = jnp.split(z, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return xs, zgate, bmat, cmat, dt


def _conv_tail(conv_in, width: int):
    """Last (W-1) conv inputs, front-padded — the decode-time conv buffer."""
    b, s, c = conv_in.shape
    w = width - 1
    if s >= w:
        return conv_in[:, s - w:, :]
    return jnp.pad(conv_in, ((0, 0), (w - s, 0), (0, 0)))


def mamba2_block(p, x, cfg, return_state: bool = False):
    """x: (B,S,D) -> (B,S,D). Chunked-scan training path."""
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    h, n = cfg.ssm_heads, cfg.ssm_state
    ph = di // h                                   # per-head dim
    y = L.norm(p["ln"], x, cfg)
    xs, zgate, bmat, cmat, dt = _mamba2_proj(p, y, cfg)
    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(p["conv"], conv_in))
    xs, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])       # (B,S,H)
    log_a = -dt * jnp.exp(p["a_log"])                                 # <= 0
    v = (xs * dt.repeat(ph, axis=-1).astype(xs.dtype)).reshape(b, s, h, ph)
    q = jnp.broadcast_to(cmat[:, :, None, :], (b, s, h, n))
    k = jnp.broadcast_to(bmat[:, :, None, :], (b, s, h, n))
    yh, final = chunked_gla(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), log_a.transpose(0, 2, 1),
                            cfg.chunk_size)
    yh = yh.transpose(0, 2, 1, 3).reshape(b, s, di)
    yh = L.norm(p["norm"], yh, cfg) * jax.nn.silu(zgate)
    out = x + yh @ p["w_out"]
    if return_state:
        return out, {"state": final, "conv": _conv_tail(conv_in, cfg.conv_width)}
    return out


def mamba2_init_state(cfg, batch, dtype=jnp.float32):
    di = cfg.ssm_expand * cfg.d_model
    h, n = cfg.ssm_heads, cfg.ssm_state
    ph = di // h
    return {"state": jnp.zeros((batch, h, n, ph), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * n), dtype)}


def mamba2_decode(p, st, x, cfg):
    """x: (B, D) one token. Returns (y: (B,D), new_state)."""
    b, d = x.shape
    di = cfg.ssm_expand * d
    h, n = cfg.ssm_heads, cfg.ssm_state
    ph = di // h
    y = L.rmsnorm(p["ln"], x[:, None, :], cfg.norm_eps)[:, 0, :]
    xs, zgate, bmat, cmat, dt = _mamba2_proj(p, y, cfg)
    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)
    cy, new_conv = _conv_decode(p["conv"], st["conv"], conv_in)
    cy = jax.nn.silu(cy)
    xs, bmat, cmat = jnp.split(cy, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])       # (B,H)
    log_a = -dt * jnp.exp(p["a_log"])
    v = (xs * dt.repeat(ph, axis=-1).astype(xs.dtype)).reshape(b, h, ph)
    q = jnp.broadcast_to(cmat[:, None, :], (b, h, n))
    k = jnp.broadcast_to(bmat[:, None, :], (b, h, n))
    yh, new_state = gla_decode_step(st["state"].transpose(0, 1, 2, 3), q, k, v, log_a)
    yh = yh.reshape(b, di)
    yh = L.rmsnorm(p["norm"], yh[:, None, :], cfg.norm_eps)[:, 0, :] * jax.nn.silu(zgate)
    return x + yh @ p["w_out"], {"state": new_state, "conv": new_conv}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory, linear attention) + sLSTM (scalar, sequential)
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    ks = jax.random.split(key, 8)
    return {
        "ln": L.rmsnorm_init(d, dtype),
        "wq": L.dense_init(ks[0], (d, d), dtype),
        "wk": L.dense_init(ks[1], (d, d), dtype),
        "wv": L.dense_init(ks[2], (d, d), dtype),
        "wz": L.dense_init(ks[3], (d, d), dtype),       # output gate branch
        "wif": L.dense_init(ks[4], (d, 2 * h), dtype),  # input & forget gate pre-acts
        "norm": L.rmsnorm_init(d, dtype),
        "wo": L.dense_init(ks[5], (d, d), dtype),
        "conv": _conv1d_init(ks[6], cfg.conv_width, d, dtype),
    }


def _mlstm_qkvg(p, y, cfg):
    b, s, d = y.shape
    h = cfg.n_heads
    hd = d // h
    c = jax.nn.silu(_causal_conv(p["conv"], y))
    q = (c @ p["wq"]).reshape(b, s, h, hd)
    k = (c @ p["wk"]).reshape(b, s, h, hd) / math.sqrt(hd)
    v = (y @ p["wv"]).reshape(b, s, h, hd)
    gates = (y @ p["wif"]).astype(jnp.float32).reshape(b, s, h, 2)
    log_f = jax.nn.log_sigmoid(gates[..., 0])            # forget (decay)
    gi = jax.nn.sigmoid(gates[..., 1])                   # input (bounded; see DESIGN)
    return q, k, v, log_f, gi


def mlstm_block(p, x, cfg, return_state: bool = False):
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    y = L.norm(p["ln"], x, cfg)
    q, k, v, log_f, gi = _mlstm_qkvg(p, y, cfg)
    k = k * gi[..., None].astype(k.dtype)
    # denominator: append a ones column to v -> last channel integrates weights
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    ya, final = chunked_gla(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v_aug.transpose(0, 2, 1, 3), log_f.transpose(0, 2, 1),
                            cfg.chunk_size)
    ya = ya.transpose(0, 2, 1, 3)
    num, den = ya[..., :hd], ya[..., hd:]
    out = num / jnp.maximum(jnp.abs(den), 1.0)
    out = out.reshape(b, s, d)
    out = L.norm(p["norm"], out, cfg) * jax.nn.silu(y @ p["wz"])
    out = x + out @ p["wo"]
    if return_state:
        return out, {"state": final, "conv": _conv_tail(y, cfg.conv_width)}
    return out


def mlstm_init_state(cfg, batch, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    return {"state": jnp.zeros((batch, h, hd, hd + 1), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, d), dtype)}


def mlstm_decode(p, st, x, cfg):
    b, d = x.shape
    h = cfg.n_heads
    hd = d // h
    y = L.rmsnorm(p["ln"], x[:, None, :], cfg.norm_eps)[:, 0, :]
    c, new_conv = _conv_decode(p["conv"], st["conv"], y)
    c = jax.nn.silu(c)
    q = (c @ p["wq"]).reshape(b, h, hd)
    k = (c @ p["wk"]).reshape(b, h, hd) / math.sqrt(hd)
    v = (y @ p["wv"]).reshape(b, h, hd)
    gates = (y @ p["wif"]).astype(jnp.float32).reshape(b, h, 2)
    log_f = jax.nn.log_sigmoid(gates[..., 0])
    gi = jax.nn.sigmoid(gates[..., 1])
    k = k * gi[..., None].astype(k.dtype)
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    ya, new_state = gla_decode_step(st["state"], q, k, v_aug, log_f)
    num, den = ya[..., :hd], ya[..., hd:]
    out = (num / jnp.maximum(jnp.abs(den), 1.0)).reshape(b, d)
    out = L.rmsnorm(p["norm"], out[:, None, :], cfg.norm_eps)[:, 0, :] \
        * jax.nn.silu(y @ p["wz"])
    return x + out @ p["wo"], {"state": new_state, "conv": new_conv}


def slstm_init(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    ks = jax.random.split(key, 3)
    return {
        "ln": L.rmsnorm_init(d, dtype),
        "w": L.dense_init(ks[0], (d, 4 * d), dtype),           # z,i,f,o pre-acts
        "r": L.dense_init(ks[1], (h, hd, 4 * hd), dtype, fan_in=hd),  # block-diag recurrence
        "norm": L.rmsnorm_init(d, dtype),
        "wo": L.dense_init(ks[2], (d, d), dtype),
    }


def _slstm_cell(p, carry, wx, cfg):
    """carry: (c, n, hprev) each (B, H, Hd); wx: (B, 4D) input pre-activations."""
    c, n, hprev = carry
    b = wx.shape[0]
    d = cfg.d_model
    h_, hd = cfg.n_heads, d // cfg.n_heads
    rec = jnp.einsum("bhd,hdk->bhk", hprev, p["r"])            # (B,H,4Hd)
    pre = wx.reshape(b, h_, 4 * hd) + rec
    z, i, f, o = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    z = jnp.tanh(z)
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f)
    o = jax.nn.sigmoid(o)
    c = f * c + i * z
    n = f * n + i
    hnew = o * c / jnp.maximum(n, 1.0)
    return (c, n, hnew), hnew


def slstm_block(p, x, cfg, return_state: bool = False):
    b, s, d = x.shape
    h_, hd = cfg.n_heads, d // cfg.n_heads
    y = L.norm(p["ln"], x, cfg)
    wx = y @ p["w"]                                            # (B,S,4D)
    init = tuple(jnp.zeros((b, h_, hd), jnp.float32) for _ in range(3))
    (c, n, hh), hs = jax.lax.scan(lambda cr, w: _slstm_cell(p, cr, w, cfg),
                                  init, jnp.moveaxis(wx, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    out = L.norm(p["norm"], hs, cfg)
    out = x + out @ p["wo"]
    if return_state:
        return out, {"c": c, "n": n, "h": hh}
    return out


def slstm_init_state(cfg, batch):
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    return {"c": jnp.zeros((batch, h, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd), jnp.float32),
            "h": jnp.zeros((batch, h, hd), jnp.float32)}


def slstm_decode(p, st, x, cfg):
    b, d = x.shape
    y = L.rmsnorm(p["ln"], x[:, None, :], cfg.norm_eps)[:, 0, :]
    wx = y @ p["w"]
    (c, n, h), hnew = _slstm_cell(p, (st["c"], st["n"], st["h"]), wx, cfg)
    hs = hnew.reshape(b, d).astype(x.dtype)
    out = L.rmsnorm(p["norm"], hs[:, None, :], cfg.norm_eps)[:, 0, :]
    return x + out @ p["wo"], {"c": c, "n": n, "h": h}

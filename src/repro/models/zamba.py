"""Zamba2-style hybrid (arXiv:2411.15242): a Mamba2 backbone with a single
*shared* attention+MLP block applied every ``shared_attn_every`` Mamba blocks.
The shared block consumes concat(hidden, original embedding) projected back to
d_model (adaptation of Zamba2's 2x-width shared block; see DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import ssm


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _layout(cfg):
    k = cfg.shared_attn_every or 6
    groups = cfg.n_layers // k
    rest = cfg.n_layers - groups * k
    return k, groups, rest


def init(key, cfg):
    dt = _dt(cfg)
    k_e, k_m, k_a, k_c, k_f = jax.random.split(key, 5)
    mk = jax.random.split(k_m, cfg.n_layers)
    params = {
        "embed": L.embed_init(k_e, (cfg.vocab_size, cfg.d_model), dt),
        "mamba": jax.vmap(lambda k: ssm.mamba2_init(k, cfg, dt))(mk),
        "shared": {
            "w_cat": L.dense_init(k_c, (2 * cfg.d_model, cfg.d_model), dt),
            "ln1": L.rmsnorm_init(cfg.d_model, dt),
            "attn": L.attn_init(k_a, cfg, dt),
            "ln2": L.rmsnorm_init(cfg.d_model, dt),
            "mlp": L.mlp_init(k_f, cfg.d_model, cfg.d_ff, "geglu", dt),
        },
        "ln_f": L.rmsnorm_init(cfg.d_model, dt),
    }
    return params


def _grouped(tree, k, groups):
    head = jax.tree.map(lambda a: a[: groups * k].reshape((groups, k) + a.shape[1:]), tree)
    rest = jax.tree.map(lambda a: a[groups * k:], tree)
    return head, rest


def _shared_attn(sp, h, x0, positions, cfg, mask):
    cat = jnp.concatenate([h, x0], axis=-1) @ sp["w_cat"]
    a = L.attention(sp["attn"], L.norm(sp["ln1"], cat, cfg),
                    positions, cfg, mask=mask)
    h = h + a
    h = h + L.mlp(sp["mlp"], L.norm(sp["ln2"], h, cfg), "geglu")
    return h


def backbone(params, x, positions, cfg, mask=None):
    k, groups, rest = _layout(cfg)
    if mask is None and cfg.attention_impl != "chunked":
        mask = L.make_attention_mask(positions, positions, causal=True,
                                     window=cfg.sliding_window)
    head, tail = _grouped(params["mamba"], k, groups)
    x0 = x

    def group(h, gp):
        def m_body(h, mp):
            return L.shard_batch(ssm.mamba2_block(mp, h, cfg)), None
        m_body = jax.checkpoint(m_body) if cfg.remat else m_body
        h, _ = jax.lax.scan(m_body, h, gp)
        h = L.shard_batch(_shared_attn(params["shared"], h, x0, positions, cfg, mask))
        return h, None

    x, _ = jax.lax.scan(group, L.shard_batch(x), head)

    def m_body(h, mp):
        return ssm.mamba2_block(mp, h, cfg), None
    x, _ = jax.lax.scan(m_body, x, tail)
    return L.norm(params["ln_f"], x, cfg)


def loss_fn(params, batch, cfg):
    tokens, targets = batch["tokens"], batch["targets"]
    b, s = tokens.shape
    x = params["embed"][tokens].astype(_dt(cfg))
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    h = backbone(params, x, positions, cfg)
    logits = (h @ params["embed"].T.astype(h.dtype)).astype(jnp.float32)
    logits = L.shard_batch(logits, None, "model")
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# serving: Mamba O(1) states + one KV cache per shared-attn application
# ---------------------------------------------------------------------------


def init_cache(cfg, batch, max_seq, dtype=None):
    dt = dtype or _dt(cfg)
    k, groups, rest = _layout(cfg)
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    one = ssm.mamba2_init_state(cfg, batch, dt)
    return {
        "mamba": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one),
        "attn_k": jnp.zeros((groups, batch, max_seq, kv, hd), dt),
        "attn_v": jnp.zeros((groups, batch, max_seq, kv, hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cache, token, pos, cfg):
    k, groups, rest = _layout(cfg)
    x = params["embed"][token[:, 0]].astype(_dt(cfg))          # (B, D)
    x0 = x
    head_p, tail_p = _grouped(params["mamba"], k, groups)
    head_s, tail_s = _grouped(cache["mamba"], k, groups)

    def group(h, inp):
        gp, gs, ck, cv = inp

        def m_body(h, ps):
            mp, mst = ps
            h, new = ssm.mamba2_decode(mp, mst, h, cfg)
            return h, new
        h, new_m = jax.lax.scan(m_body, h, (gp, gs))
        cat = (jnp.concatenate([h, x0], axis=-1) @ params["shared"]["w_cat"])[:, None, :]
        a, ck, cv = L.attention_decode(
            params["shared"]["attn"],
            L.norm(params["shared"]["ln1"], cat, cfg),
            ck, cv, pos, cfg, window=cfg.sliding_window)
        h = h + a[:, 0, :]
        y = L.rmsnorm(params["shared"]["ln2"], h[:, None, :], cfg.norm_eps)
        h = h + L.mlp(params["shared"]["mlp"], y, "geglu")[:, 0, :]
        return h, (new_m, ck, cv)

    h, (new_head, new_k, new_v) = jax.lax.scan(
        group, x, (head_p, head_s, cache["attn_k"], cache["attn_v"]))

    def m_body(h, ps):
        mp, mst = ps
        h, new = ssm.mamba2_decode(mp, mst, h, cfg)
        return h, new
    h, new_tail = jax.lax.scan(m_body, h, (tail_p, tail_s))

    new_mamba = jax.tree.map(
        lambda a, b: jnp.concatenate(
            [a.reshape((groups * k,) + a.shape[2:]), b], axis=0),
        new_head, new_tail)
    h = L.rmsnorm(params["ln_f"], h[:, None, :], cfg.norm_eps)
    logits = h @ params["embed"].T.astype(h.dtype)
    new_cache = {"mamba": new_mamba, "attn_k": new_k, "attn_v": new_v,
                 "pos": cache["pos"] + 1}
    return logits, new_cache


def _shared_attn_kv(sp, h, x0, positions, cfg, mask):
    """_shared_attn variant that also returns the (rope'd) K/V for the cache."""
    b, s, _ = h.shape
    cat = jnp.concatenate([h, x0], axis=-1) @ sp["w_cat"]
    hn = L.norm(sp["ln1"], cat, cfg)
    q, k, v = L._qkv(sp["attn"], hn, cfg)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    if cfg.attention_impl == "chunked":
        o = L.chunked_attention(q, k, v, positions, positions, causal=True,
                                window=cfg.sliding_window,
                                block=cfg.attention_block)
    else:
        o = L.dot_attention(q, k, v, mask,
                            kv_heads_repeat=cfg.n_heads // cfg.n_kv_heads)
    h = h + o.reshape(b, s, -1) @ sp["attn"]["wo"]
    h = h + L.mlp(sp["mlp"], L.norm(sp["ln2"], h, cfg), "geglu")
    return h, (k, v)


def prefill(params, batch, cfg):
    """Forward over the prompt emitting all Mamba final states and the shared
    attention block's per-application K/V cache."""
    k_, groups, rest = _layout(cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens].astype(_dt(cfg))
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    mask = (None if cfg.attention_impl == "chunked" else
            L.make_attention_mask(positions, positions, causal=True,
                                  window=cfg.sliding_window))
    head_p, tail_p = _grouped(params["mamba"], k_, groups)
    x0 = x

    def m_body(h, mp):
        h, st = ssm.mamba2_block(mp, h, cfg, return_state=True)
        return L.shard_batch(h), st

    def group(h, gp):
        h, sts = jax.lax.scan(m_body, h, gp)
        h, (kk, vv) = _shared_attn_kv(params["shared"], h, x0, positions, cfg, mask)
        return L.shard_batch(h), (sts, kk, vv)

    h, (head_states, ks, vs) = jax.lax.scan(group, L.shard_batch(x), head_p)
    h, tail_states = jax.lax.scan(m_body, h, tail_p)

    mamba_states = jax.tree.map(
        lambda a, t: jnp.concatenate(
            [a.reshape((groups * k_,) + a.shape[2:]), t], axis=0),
        head_states, tail_states)
    h = L.norm(params["ln_f"], h, cfg)
    logits = h[:, -1:, :] @ params["embed"].T.astype(h.dtype)
    cache = {"mamba": mamba_states, "attn_k": ks, "attn_v": vs,
             "pos": jnp.asarray(s, jnp.int32)}
    return logits, cache


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------


def param_specs(cfg, mode: str = "train"):
    policy = cfg.train_sharding if mode == "train" else cfg.serve_sharding
    fsdp = "data" if policy == "fsdp" else None
    mamba = {
        "ln": {"scale": P(None, None)},
        "w_in": P(None, fsdp, "model"),
        "conv": {"w": P(None, None, "model"), "b": P(None, "model")},
        "a_log": P(None, None),
        "dt_bias": P(None, None),
        "norm": {"scale": P(None, None)},
        "w_out": P(None, "model", fsdp),
    }
    kv_shardable = cfg.n_kv_heads % 16 == 0
    attn = {
        "wq": P(fsdp, "model"),
        "wk": P(fsdp, "model" if kv_shardable else None),
        "wv": P(fsdp, "model" if kv_shardable else None),
        "wo": P("model", fsdp),
    }
    shared = {
        "w_cat": P(fsdp, "model"),
        "ln1": {"scale": P(None)},
        "attn": attn,
        "ln2": {"scale": P(None)},
        "mlp": {"wi": P(fsdp, "model"), "wg": P(fsdp, "model"),
                "wo": P("model", fsdp)},
    }
    return {"embed": P("model", fsdp), "mamba": mamba, "shared": shared,
            "ln_f": {"scale": P(None)}}


def cache_specs(cfg):
    kv_shardable = cfg.n_kv_heads % 16 == 0
    attn_spec = (P(None, "data", None, "model", None) if kv_shardable
                 else P(None, "data", "model", None, None))
    return {
        "mamba": {"state": P(None, "data", None, None, "model"),
                  "conv": P(None, "data", None, "model")},
        "attn_k": attn_spec, "attn_v": attn_spec, "pos": P(),
    }

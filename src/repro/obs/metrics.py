"""`MetricStream`: streaming per-round metrics out of a running scan
(DESIGN.md §13).

The scan driver's contract (core/rounds.py) is that K federated rounds are
ONE XLA dispatch — which makes the operator blind for the whole dispatch.
The naive fix, a host callback in the scan body, is NOT free (measured on
the CPU backend): the mere presence of a callback custom-call inside
`lax.scan` costs ~0.15-0.3 ms per iteration — comparable to a whole MLP
round — even when `lax.cond`-gated to fire rarely. Worse, ANY effect in a
program — even one no-op callback appended after the scan — drops the
whole executable off the runtime's fast dispatch path, slowing the
unrelated scan itself by a further ~3-4% (measured).

So the tap keeps the compute program 100% pure: K rounds are split into
ceil(K/F) flush-chunks (F = ``flush_every``), each chunk runs through THE
SAME cached jitted scan as the bare engine (`rounds._scan_jit` — literally
the same compiled executable, so trajectories and stacked metrics are
bitwise-identical; pinned in tests/test_obs.py), and right after each
asynchronous chunk dispatch the chunk's stacked metric arrays — still
in-flight device futures — are handed to a daemon *drainer* thread. The
drainer blocks on the futures (off the dispatch path), builds rows, and
feeds the sinks, so rows hit the JSONL file as each chunk completes while
the host keeps enqueueing subsequent chunks. The dispatch thread never
waits on metrics or file I/O; measured overhead of an active stream is
~1-2% at ~0.25 ms/round (benchmarks/obs_bench.py, <5% acceptance bar).

``transport="callback"`` instead flushes each chunk through a separate
tiny jitted program holding one `jax.experimental.io_callback` on the
(F, M) float32 metric matrix + (F,) round numbers. It exists for backends
where host reads of in-flight futures are undesirable, and as the measured
baseline: even this microscopic effectful companion costs ~2.3 ms per
flush on CPU, because ANY effect drops a program off the runtime's fast
dispatch path — which is why it is not the default.

Ordering: one flush per chunk, chunks complete in dispatch order, and the
single drainer consumes a single queue — round flushes AND `emit_event`
rows alike — so everything arrives in dispatch order without any caller
blocking; `sync()` (effects barrier + queue join) makes pending rows
visible when you need to read them. Rate limiting (`log_every`) is
applied host-side in the drainer — the device→host payload is a few KB
per chunk either way, and host-side thinning keeps the compiled programs
independent of the log rate.

Rows are flat dicts (``{"kind": "round", "t": <global round>, <metric>:
float, ...}``) appended to :attr:`MetricStream.rows` and fanned out to the
sinks (obs/sinks.py). `emit_event` lets drivers interleave eval results and
host spans into the same ordered log. Whatever the step's metrics dict
carries streams untouched — a DP run (core/privacy.py, DESIGN.md §15) adds
``dp_epsilon`` (the RDP accountant's composed ε through round t, computed
in-graph from the row's own ``t``), ``dp_clip_frac``, and
``dp_noise_norm`` rows this way, and the run manifest (obs/sinks.py
``extra=``) records the matching calibration + end-of-run ε.
"""
from __future__ import annotations

import queue
import threading
import weakref

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback


def _row_floats(names, vec) -> dict:
    """The ONE place metric values become row values: a float32 cast (on
    device for the callback transport, here otherwise), then python float
    — so streamed rows are bit-identical across drivers and transports.
    `tolist` runs the cast→float loop in C; one np.asarray covers both the
    loop driver's list of scalars and the scan transports' matrix rows."""
    return dict(zip(names, np.asarray(vec, dtype=np.float32).tolist()))


def _drain_loop(stream_ref, q):
    """Daemon drainer: builds rows from raw flushes and feeds the sinks,
    off the dispatch thread. Holds only a weakref to the stream so the
    thread cannot keep it alive; exits once the stream is collected."""
    while True:
        try:
            kind, *item = q.get(timeout=1.0)
        except queue.Empty:
            if stream_ref() is None:
                return
            continue
        try:
            stream = stream_ref()
            if stream is not None:
                if kind == "rounds":
                    stream._flush_rows(*item)
                else:
                    with stream._lock:
                        stream._emit(item[0])
                        stream._flush_sinks()
        finally:
            q.task_done()


class MetricStream:
    """Streams per-round scalar metrics to host sinks while the scan runs.

    Use through the drivers — ``run_rounds(..., obs=stream)`` /
    ``run_feature_rounds(..., obs=stream)`` or any `core.algorithms` /
    `core.baselines` driver's ``obs=`` — or call :meth:`run` directly in
    place of `rounds.scan_rounds`. The returned ``(state, stacked
    metrics)`` are bitwise-identical to the un-observed engine's.

    Parameters
    ----------
    sinks : iterable of sink objects (obs/sinks.py); every row is fanned
        out to each in order. Rows are always also kept in :attr:`rows`.
    log_every : emit every Nth round row (host-side thinning; the global
        round number t is used, so chunking does not shift the cadence).
        Eval/span rows from `emit_event` are never thinned.
    flush_every : rounds per flush-chunk dispatch (F above). Smaller =
        lower latency but more dispatches (chunking a sub-ms-round scan
        into 50-round pieces costs ~5% by itself); the default matches
        the drivers' typical dispatch size so short runs stay one chunk.
        Capped at the dispatch size automatically.
    transport : "future" (default) hands the chunk's in-flight device
        arrays to the drainer thread, which blocks on them off the
        dispatch path — the compute program stays effect-free. "callback"
        flushes through a companion jitted `io_callback` program instead
        (~2.3 ms/flush on CPU; see module docstring).
    """

    def __init__(self, sinks=(), log_every: int = 1, flush_every: int = 200,
                 name: str = "run", transport: str = "future"):
        if transport not in ("future", "callback"):
            raise ValueError(
                f"unknown transport {transport!r} (choose future|callback)")
        self.sinks = tuple(sinks)
        self.log_every = max(1, int(log_every))
        self.flush_every = max(1, int(flush_every))
        self.transport = transport
        self.name = name
        self.rows: list = []
        self._lock = threading.Lock()
        self._queue: queue.Queue | None = None
        # compiled flush programs, keyed by the step's metric-name tuple
        # (the chunk scans themselves come from rounds.py's weak caches)
        self._flushers: dict = {}

    # -- host side ----------------------------------------------------------

    def _emit(self, row: dict):
        self.rows.append(row)
        for s in self.sinks:
            s.emit(row)

    def _flush_sinks(self):
        for s in self.sinks:
            f = getattr(s, "flush", None)
            if f is not None:
                f()

    def _ensure_drainer(self) -> queue.Queue:
        if self._queue is None:
            self._queue = queue.Queue()
            threading.Thread(target=_drain_loop,
                             args=(weakref.ref(self), self._queue),
                             daemon=True,
                             name=f"obs-drain-{self.name}").start()
        return self._queue

    def _flush_rows(self, names, t_vec, mat):
        """Drainer target: one (F,) t-vector + the chunk's metric columns
        per flush-chunk — an (F, M) np matrix from the callback transport,
        or a list of per-metric device arrays (possibly still in flight)
        from the future transport; blocking on those here is the point.
        The single drainer + the lock keep rows in round order even with
        concurrent emit_event calls."""
        t_list = np.asarray(t_vec).tolist()
        if not isinstance(mat, np.ndarray):
            mat = np.stack([np.asarray(c).astype(np.float32) for c in mat],
                           axis=1)
        # same cast→float convention as _row_floats (f32 astype + tolist),
        # hoisted to ONE C call for the whole chunk: per-row np indexing
        # is most of the drainer's CPU on small hosts
        vals = mat.astype(np.float32, copy=False).tolist()
        with self._lock:
            for i, t in enumerate(t_list):
                t = int(t)
                if t % self.log_every:
                    continue
                # reserved keys last so a step metric literally named "t"
                # or "kind" cannot shadow the row schema (it still reaches
                # the stacked history as round_t / round_kind)
                row = dict(zip(names, vals[i]))
                row["kind"] = "round"
                row["t"] = t
                self._emit(row)
            self._flush_sinks()

    def emit_event(self, row: dict):
        """Append a non-round row (eval result, host span, ledger snapshot)
        to the log, in order with the streamed round rows: once the drainer
        exists, events ride the same queue as the round flushes, so an
        event emitted after a chunk dispatch lands after that chunk's rows
        without anyone blocking."""
        if self._queue is not None:
            self._queue.put(("event", dict(row)))
        else:
            with self._lock:
                self._emit(dict(row))
                self._flush_sinks()

    def sync(self):
        """Block until every dispatched flush has reached the sinks (so
        :attr:`rows` reflects all dispatched rounds)."""
        jax.effects_barrier()
        if self._queue is not None:
            self._queue.join()

    def close(self):
        """Drain pending flushes and close every sink."""
        self.sync()
        for s in self.sinks:
            s.close()

    # -- device side --------------------------------------------------------

    def _flusher(self, names):
        """The tiny effectful companion program for one metric-name set:
        stacks the chunk's metrics to an (F, M) float32 matrix and hands it
        (with the (F,) round numbers) to the drainer queue via ONE
        io_callback. Cached per names-tuple so the callback closure always
        carries the right column labels."""
        fn = self._flushers.get(names)
        if fn is None:
            stream_ref = weakref.ref(self)
            q = self._ensure_drainer()

            def on_flush(t_vec, mat):
                if stream_ref() is not None:
                    q.put(("rounds", names, np.asarray(t_vec),
                           np.asarray(mat)))

            def flush(t_vec, ms):
                mat = jnp.stack([ms[k].astype(jnp.float32) for k in names],
                                axis=1)
                # one callback per chunk; cross-chunk order comes from
                # dispatch-queue order, so no ordering token is needed
                io_callback(on_flush, None, t_vec, mat, ordered=False)

            fn = jax.jit(flush)
            self._flushers[names] = fn
        return fn

    def run(self, step_fn, state, inputs, driver: str = "scan"):
        """Drop-in replacement for ``rounds.ENGINES[driver](step_fn, state,
        inputs)`` that additionally streams each round's metrics to the
        sinks. Returns the same (state, stacked (K,) metrics) — bitwise.

        Returns as soon as the compute is dispatched and the flushes are
        queued; rows become visible as chunks complete. Call :meth:`sync`
        (or :meth:`close`) before reading :attr:`rows` directly."""
        from repro.core import rounds as rounds_lib

        if driver == "loop":
            return self._run_loop(step_fn, state, inputs)
        if driver != "scan":
            raise ValueError(f"unknown driver {driver!r} (choose scan|loop)")
        k = inputs.num_rounds
        f = min(self.flush_every, k)
        scan = rounds_lib._scan_jit(step_fn)
        parts = []
        for c0 in range(0, k, f):
            chunk = (inputs if f == k else
                     jax.tree.map(lambda x: x[c0: c0 + f], inputs))
            state, ms = scan(state, chunk)
            if ms:
                names = tuple(sorted(ms))
                if self.transport == "future":
                    # hand the in-flight device arrays straight to the
                    # drainer; it blocks on them off the dispatch path
                    self._ensure_drainer().put(
                        ("rounds", names, chunk.t, [ms[k] for k in names]))
                else:
                    self._flusher(names)(chunk.t, ms)
            parts.append(ms)
        if not parts:
            stacked = {}
        elif len(parts) == 1:
            stacked = parts[0]
        else:
            stacked = {key: jnp.concatenate([p[key] for p in parts])
                       for key in parts[0]}
        return state, stacked

    def _run_loop(self, step_fn, state, inputs):
        """Loop-driver tap: one dispatch per round already returns metrics
        to the host, so rows are built directly (through the same
        `_row_floats` cast as the scan path — bit-identical rows)."""
        from repro.core import rounds as rounds_lib

        step = rounds_lib._step_jit(step_fn)
        ms = []
        for r in range(inputs.num_rounds):
            inp = jax.tree.map(lambda x: x[r], inputs)
            state, m = step(state, inp)
            ms.append(m)
            if not m:
                continue
            t = int(inp.t)
            if t % self.log_every:
                continue
            names = tuple(sorted(m))
            row = _row_floats(names, [np.asarray(m[nm]) for nm in names])
            row["kind"] = "round"
            row["t"] = t
            if self._queue is not None:   # keep order with prior scan runs
                self._queue.put(("event", row))
            else:
                with self._lock:
                    self._emit(row)
                    self._flush_sinks()
        stacked = ({key: jnp.stack([m[key] for m in ms]) for key in ms[0]}
                   if ms else {})
        return state, stacked

"""Pluggable row sinks + the run manifest (DESIGN.md §13).

A *row* is a flat JSON-serializable dict; by convention it carries a
``"kind"`` discriminator (``round`` — one scanned federated round, ``eval``
— an eval-hook result, ``span`` — a host wall-clock span, ``comm`` — a
`CommLedger` snapshot). A *sink* is anything with ``emit(row)`` and
``close()`` (plus an optional ``flush()``, called once per flush-chunk);
`MetricStream` fans every row out to its sinks in order.

The *manifest* records what a run WAS — config, mesh/devices, codec,
topology, git sha, jax version, and (optionally) the per-dispatch HLO
flops/bytes from `roofline` — as one JSON document next to the JSONL log,
so a metrics file is interpretable without the shell history that produced
it. `bench_json` is the shared BENCH_*.json emitter: payload to ``path``,
manifest to ``path + ".manifest.json"`` (benchmarks/{comm,shard,feature,
obs}_bench all write through it).
"""
from __future__ import annotations

import csv
import json
import os
import subprocess
import time
from typing import Optional


def _jsonable(v):
    """Best-effort conversion of a row/manifest value to JSON-serializable
    form (numpy/jax scalars -> python; unknown objects -> repr)."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if hasattr(v, "item") and getattr(v, "ndim", None) == 0:
        return v.item()
    if hasattr(v, "_asdict"):          # NamedTuple configs (FLConfig etc.)
        return _jsonable(v._asdict())
    if hasattr(v, "__dict__") and type(v).__module__ != "builtins":
        try:
            return _jsonable(vars(v))
        except TypeError:
            pass
    return repr(v)


class JsonlSink:
    """One JSON object per line. Rows are buffered; `MetricStream`'s
    drainer calls :meth:`flush` once per flush-chunk, so the file is
    tail -f-able at chunk granularity without paying one fflush per row
    (which dominates the sink cost at sub-ms rounds on small hosts)."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "w")

    def emit(self, row: dict):
        # round rows are already plain floats/ints/strs — serialize those
        # on the fast path and only pay _jsonable's recursive conversion
        # for rows that actually carry numpy/jax/exotic values
        try:
            line = json.dumps(row)
        except (TypeError, ValueError):
            line = json.dumps(_jsonable(row))
        self._f.write(line + "\n")

    def flush(self):
        self._f.flush()

    def close(self):
        self._f.close()


class CsvSink:
    """Buffers rows and writes one CSV at close with the union of all keys
    (first-seen column order); missing cells are empty."""

    def __init__(self, path: str):
        self.path = path
        self._rows: list = []

    def emit(self, row: dict):
        self._rows.append(_jsonable(row))

    def close(self):
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        cols: list = []
        for r in self._rows:
            for k in r:
                if k not in cols:
                    cols.append(k)
        with open(self.path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=cols, restval="")
            w.writeheader()
            w.writerows(self._rows)


class StdoutSink:
    """`k=v` lines to stdout (the historical train-loop log format)."""

    def __init__(self, prefix: str = ""):
        self.prefix = prefix

    def emit(self, row: dict):
        body = " ".join(
            f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
            for k, v in _jsonable(row).items())
        print((self.prefix + " " + body) if self.prefix else body, flush=True)

    def close(self):
        pass


class MemorySink:
    """Keeps rows in a list (tests, notebooks)."""

    def __init__(self):
        self.rows: list = []

    def emit(self, row: dict):
        self.rows.append(dict(row))

    def close(self):
        pass


# ---------------------------------------------------------------------------
# run manifest
# ---------------------------------------------------------------------------


def git_sha() -> Optional[str]:
    """HEAD sha of the repo this package lives in, or None (e.g. when
    installed from a wheel — the manifest must never fail a run)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else None
    except (OSError, subprocess.SubprocessError):
        return None


def _topology_info(topology) -> Optional[dict]:
    if topology is None:
        return None
    info = {"name": getattr(topology, "name", type(topology).__name__),
            "num_shards": getattr(topology, "num_shards", 1)}
    mesh = getattr(topology, "mesh", None)
    if mesh is not None:
        info["mesh_axes"] = dict(zip(mesh.axis_names,
                                     [int(s) for s in mesh.devices.shape]))
        info["client_axes"] = list(getattr(topology, "axes", ()))
    return info


def run_manifest(config=None, *, codec=None, topology=None, cost=None,
                 extra=None) -> dict:
    """Everything needed to interpret a metrics log, as one dict:
    environment (jax version, backend, device fleet), provenance (git sha,
    wall time), protocol (config, codec, topology/mesh), and optionally the
    per-dispatch HLO cost (``cost=`` — see
    `roofline.analysis.jit_cost_summary`)."""
    import jax

    man = {
        "time": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "git_sha": git_sha(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "device_kind": jax.devices()[0].device_kind,
        "config": _jsonable(config),
        "codec": getattr(codec, "name", None) if codec is not None
                 else (codec if isinstance(codec, str) else None),
        "topology": _topology_info(topology),
    }
    if cost is not None:
        man["hlo_cost"] = _jsonable(cost)
    if extra:
        man.update(_jsonable(dict(extra)))
    return man


def write_manifest(path: str, config=None, *, codec=None, topology=None,
                   cost=None, extra=None) -> dict:
    """Build `run_manifest` and write it to ``path`` as indented JSON."""
    man = run_manifest(config, codec=codec, topology=topology, cost=cost,
                       extra=extra)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(man, f, indent=1)
    return man


def bench_json(path: str, payload, *, manifest: Optional[dict] = None,
               **manifest_kwargs):
    """The shared BENCH_*.json emitter: payload (unchanged schema) to
    ``path``, run manifest to ``path + ".manifest.json"``. All benchmarks
    write through this so every artifact records the environment that
    produced it."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(_jsonable(payload), f, indent=1)
    man = manifest if manifest is not None else run_manifest(**manifest_kwargs)
    with open(path + ".manifest.json", "w") as f:
        json.dump(_jsonable(man), f, indent=1)
    print(f"# wrote {path} (+ {os.path.basename(path)}.manifest.json)",
          flush=True)

"""Span-based phase tracing for the federated round (DESIGN.md §13).

Two clocks, one vocabulary:

* **In-jit phases** (`phase`): `jax.named_scope` annotations compiled into
  the HLO metadata, so an xprof/perfetto dump attributes device time to
  protocol phases — ``round → client-compute → codec-encode → collective →
  surrogate-solve``. Scopes are free at runtime (they only label ops at
  trace time) and therefore safe on the hot path; they are applied inside
  `core/topology.py`, `core/optimizer.py`, `core/fed.py`, and the round
  drivers unconditionally.
* **Host spans** (`HostSpans`): wall-clock timing at dispatch boundaries —
  the scan dispatch itself, eval hooks, checkpoint writes — paired with
  `jax.profiler.TraceAnnotation` so the same names appear on the profiler
  timeline. Spans are plain rows (``kind="span"``) emitted through the
  sink API, so a JSONL log interleaves rounds, evals, and spans in order.

`profile(logdir)` wraps a whole run in `jax.profiler.start_trace` /
`stop_trace`; the resulting directory opens in xprof/perfetto and contains
the named scopes above (exercised by the CI obs-smoke job).
"""
from __future__ import annotations

import contextlib
import functools
import os
import time

import jax

# the canonical phase names, in protocol order (DESIGN.md §13); free-form
# names are allowed everywhere, this is the shared vocabulary
PHASES = ("round", "client-compute", "codec-encode", "collective",
          "aggregate", "head-compute", "batch-select", "surrogate-solve")


def phase(name: str):
    """In-jit phase annotation: a `jax.named_scope` context manager. Use
    around trace-time code regions; compiles to op metadata, costs nothing
    at runtime."""
    return jax.named_scope(name)


def scoped(name: str, fn=None):
    """Wrap fn so every call runs under `phase(name)`. Usable directly —
    ``scoped("round", step_fn)`` (the round drivers label the scanned step
    this way) — or as a decorator: ``@scoped("surrogate-solve")``."""
    if fn is None:
        return lambda f: scoped(name, f)

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        with jax.named_scope(name):
            return fn(*args, **kwargs)
    return wrapped


class HostSpans:
    """Host-side wall-clock spans at dispatch boundaries.

    Each completed span appends ``{"kind": "span", "span": name,
    "dur_s": ..., **attrs}`` to :attr:`spans` and, when a stream (any object
    with ``emit_event(row)``, e.g. `obs.metrics.MetricStream`) is attached,
    emits the row through it — so the JSONL log carries dispatch timings
    next to the round rows they bracket. The span body also runs under
    `jax.profiler.TraceAnnotation(name)`, putting the same name on the
    profiler timeline.
    """

    def __init__(self, stream=None):
        self.stream = stream
        self.spans: list = []

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        t0 = time.perf_counter()
        with jax.profiler.TraceAnnotation(name):
            yield
        row = {"kind": "span", "span": name,
               "dur_s": time.perf_counter() - t0}
        row.update(attrs)
        self.spans.append(row)
        if self.stream is not None:
            self.stream.emit_event(row)


@contextlib.contextmanager
def profile(logdir: str):
    """Profile the enclosed block with `jax.profiler` into ``logdir``
    (created if missing). The dump contains the `phase` named scopes and
    every `HostSpans` TraceAnnotation; open it with xprof or
    ui.perfetto.dev."""
    os.makedirs(logdir, exist_ok=True)
    jax.profiler.start_trace(logdir)
    try:
        yield logdir
    finally:
        jax.profiler.stop_trace()

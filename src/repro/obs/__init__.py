"""Observability layer for the scan-compiled FL stack (DESIGN.md §13).

Three pieces, importable separately (none of them imports repro.core at
module scope, so core modules are free to use `repro.obs.trace` phases):

* :mod:`repro.obs.metrics` — `MetricStream`, the streaming tap that gets
  per-round scalar metrics OUT of a running ``lax.scan`` dispatch via a
  chunked, ordered `io_callback`, without unrolling the scan or changing
  the trajectory (bitwise — pinned in tests/test_obs.py).
* :mod:`repro.obs.trace` — `phase` (in-jit `jax.named_scope` annotations
  for the protocol phases: round → client-compute → codec-encode →
  collective → surrogate-solve), `HostSpans` (host wall-clock spans at
  dispatch boundaries via `jax.profiler.TraceAnnotation`), and
  `profile(dir)` (an xprof/perfetto trace of the whole run).
* :mod:`repro.obs.sinks` — pluggable row consumers (JSONL/CSV/stdout/
  memory), the run manifest (config, mesh, codec, topology, git sha,
  per-dispatch HLO cost), and `bench_json` (the BENCH_*.json emitter the
  benchmarks share).
"""
from repro.obs.metrics import MetricStream
from repro.obs.sinks import (CsvSink, JsonlSink, MemorySink, StdoutSink,
                             bench_json, run_manifest, write_manifest)
from repro.obs.trace import HostSpans, phase, profile

__all__ = [
    "MetricStream", "JsonlSink", "CsvSink", "StdoutSink", "MemorySink",
    "bench_json", "run_manifest", "write_manifest", "HostSpans", "phase",
    "profile",
]

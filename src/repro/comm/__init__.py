"""Communication-compression subsystem (DESIGN.md §10).

The paper's headline beyond convergence speed is communication load per
round (Fig. 3 plots bytes-on-wire); this package *reduces* those bytes
instead of merely accounting for them. Three modules:

  codecs.py          lossy upload codecs behind one ``Codec`` protocol
                     (identity, stochastic-rounding int8/int4 with per-chunk
                     scales, top-k sparsification, top-k∘quantize chain)
  error_feedback.py  per-client compression residuals carried through the
                     scan as part of the round carry (EF re-injects what the
                     codec dropped, next round)
  accounting.py      exact bytes-on-wire bookkeeping — subsumes the Fig.-3
                     float counters formerly inlined in core/fed.py

The SSCA surrogate recursion is unusually compression-friendly: the
ρ-averaging of eq. (9) already low-pass-filters the q-uploads, so unbiased
codecs (stochastic rounding) slot in without touching the convergence story,
and biased ones (top-k) are debiased-in-the-limit by error feedback.
"""
from repro.comm.accounting import (CommLedger, comm_load_per_round,
                                   compression_ratio, feature_round_bytes,
                                   sample_round_bytes, vector_nbytes)
from repro.comm.codecs import (Chain, Codec, Identity, StochasticQuantizer,
                               TopK, flatten_stacked, flatten_tree,
                               make_codec, tree_flat_dim)
from repro.comm.error_feedback import (CommCarry, ef_init, ef_init_stacked,
                                       ef_roundtrip, with_comm_carry)

__all__ = [
    "Chain", "Codec", "CommCarry", "CommLedger", "Identity",
    "StochasticQuantizer", "TopK", "comm_load_per_round", "compression_ratio",
    "ef_init", "ef_init_stacked", "ef_roundtrip", "feature_round_bytes",
    "flatten_stacked", "flatten_tree", "make_codec", "sample_round_bytes",
    "tree_flat_dim", "vector_nbytes", "with_comm_carry",
]

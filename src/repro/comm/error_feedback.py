"""Error feedback (EF14/EF-SGD style) for compressed q-uploads.

Each client keeps a residual r_i of what its codec dropped so far; before
encoding it adds the residual back:

    target  = q_i + r_i
    enc     = codec.encode(target)          # crosses the wire
    r_i'    = target - decode(enc)          # re-injected next round

For unbiased codecs (stochastic rounding) EF is a harmless variance
reducer; for biased ones (top-k) it is what makes the trajectory track the
dense one — every coordinate's accumulated mass eventually exceeds the
top-k threshold and gets flushed, so as k -> P the compressed trajectory
recovers the dense trajectory exactly (tests/test_comm.py pins k = P).

The residuals are *state*: they ride through the scan-compiled round driver
as part of the carry, wrapped in :class:`CommCarry` next to the optimizer
state (``core/rounds.py::unwrap_comm`` peels the wrapper when extracting
params). Under partial participation a non-selected client neither uploads
nor touches its residual — ``ef_roundtrip(active=...)`` freezes it.

Two layouts exist for the per-client residual matrix:

* the **dense** ``(I, P)`` array (``ef_init_stacked``) — every client's row
  enters the round compute, non-participants frozen via ``active``; the
  bit-level reference for small I;
* the **keyed** :class:`EFStore` (``ef_store_init``) for the O(S) cohort
  engine (DESIGN.md §14) — the same ``(I, P)`` backing lives OUTSIDE the
  per-round compute (device-resident by default, host-offloadable behind
  the same interface); each round gathers the cohort's ``(S, P)`` slice in
  and scatters the updated slice back, O(S·P) touched per round. A
  non-participant's row is never read or written, so the two layouts stay
  bit-equal (pinned in tests/test_cohort.py).

Ordering with DP (DESIGN.md §15): the ``dp=`` clip+noise stage of
core/topology.py runs BEFORE ``ef_roundtrip``, so ``target`` — and hence
the residual the client carries between rounds — is built from the
already-privatized upload. The residual never stores raw (pre-noise)
signal: EF state leaking cannot undo the mechanism, and what EF re-injects
next round is codec error on privatized data, not deferred private signal.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class CommCarry(NamedTuple):
    """Scan carry = inner optimizer state + per-client EF residuals."""
    opt: object                    # SSCAState / SGDState / ... (has .params)
    ef: object                     # residual vector(s): (P,), (I, P), or dict


def ef_init(dim: int):
    """Residual for a single P-dim upload stream (e.g. the pjit train loop's
    all-reduced gradient, or the feature-based head upload)."""
    return jnp.zeros((dim,), jnp.float32)


def ef_init_stacked(num_clients: int, dim: int):
    """Per-client residuals for sample-based rounds: one (P,) vector each."""
    return jnp.zeros((num_clients, dim), jnp.float32)


class EFStore(NamedTuple):
    """Keyed per-client residual store for the cohort engine: the (I, P)
    backing stays out of the round's (S, ...) compute; rounds touch only the
    cohort's rows via :meth:`gather` / :meth:`scatter`.

    A NamedTuple is a registered pytree, so the store rides the scan carry
    (inside :class:`CommCarry`) unchanged — and because the scatter is the
    carry's only use of the backing, XLA donates/aliases the buffer across
    scan iterations: the update is in-place, not an (I, P) copy per round.
    """
    data: jnp.ndarray              # (I, P) residual backing

    @property
    def num_clients(self):
        return self.data.shape[0]

    @property
    def dim(self):
        return self.data.shape[1]

    def gather(self, ids):
        """(S,) client ids -> (S, P) residual rows for this round's cohort."""
        return jnp.take(self.data, ids, axis=0)

    def scatter(self, ids, rows):
        """Write the cohort's updated rows back; every other client's
        residual is bit-untouched (never read, never written)."""
        return self._replace(data=self.data.at[ids].set(rows))


def ef_store_init(num_clients: int, dim: int,
                  host_offload: bool = False) -> EFStore:
    """Zero-initialized keyed residual store for `fed.cohort_round`.

    ``host_offload=True`` places the backing in the backend's pinned host
    memory space when one exists (the (I, P) matrix at I = 1e6 can exceed
    accelerator HBM); gather/scatter keep working behind the identical
    interface — XLA stages the (S, P) slices through device memory. Falls
    back to default device placement (with no error) on backends without a
    pinned_host memory space, so callers never branch."""
    data = jnp.zeros((num_clients, dim), jnp.float32)
    if host_offload:
        try:
            mem = jax.devices()[0].memory("pinned_host")
            data = jax.device_put(data, mem)
        except Exception:       # backend has no pinned_host space — stay put
            pass
    return EFStore(data=data)


def with_comm_carry(codec, body):
    """Wrap a round body into a (state, inp) scan step with the EF carry
    handled in ONE place (every driver shares this, so no copy can forget
    the residual rewrap). ``body(state, inp, ef) -> (new_state, new_ef,
    metrics)`` receives ef=None when no codec is configured; with a codec
    the step's state is CommCarry(opt=state, ef=residuals)."""
    def step(state, inp):
        if codec is None:
            new, _, metrics = body(state, inp, None)
            return new, metrics
        new, new_ef, metrics = body(state.opt, inp, state.ef)
        return CommCarry(opt=new, ef=new_ef), metrics

    return step


def ef_roundtrip(codec, x, residual, key=None, active=None):
    """One error-feedback compression step on a flat upload vector.

    Returns (enc, x_hat, new_residual). ``active`` (0/1 scalar, typically a
    participation-mask entry under vmap) freezes the residual of a client
    that did not upload this round; its x_hat is zero-masked server-side by
    the aggregation weights, so only the residual needs guarding.

    Conservation invariant (any codec): x_hat + new_residual == x + residual.
    """
    target = x + residual
    enc, x_hat = codec.roundtrip(target, key)
    new_residual = target - x_hat
    if active is not None:
        new_residual = jnp.where(active > 0, new_residual, residual)
    return enc, x_hat, new_residual

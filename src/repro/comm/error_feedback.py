"""Error feedback (EF14/EF-SGD style) for compressed q-uploads.

Each client keeps a residual r_i of what its codec dropped so far; before
encoding it adds the residual back:

    target  = q_i + r_i
    enc     = codec.encode(target)          # crosses the wire
    r_i'    = target - decode(enc)          # re-injected next round

For unbiased codecs (stochastic rounding) EF is a harmless variance
reducer; for biased ones (top-k) it is what makes the trajectory track the
dense one — every coordinate's accumulated mass eventually exceeds the
top-k threshold and gets flushed, so as k -> P the compressed trajectory
recovers the dense trajectory exactly (tests/test_comm.py pins k = P).

The residuals are *state*: they ride through the scan-compiled round driver
as part of the carry, wrapped in :class:`CommCarry` next to the optimizer
state (``core/rounds.py::unwrap_comm`` peels the wrapper when extracting
params). Under partial participation a non-selected client neither uploads
nor touches its residual — ``ef_roundtrip(active=...)`` freezes it.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class CommCarry(NamedTuple):
    """Scan carry = inner optimizer state + per-client EF residuals."""
    opt: object                    # SSCAState / SGDState / ... (has .params)
    ef: object                     # residual vector(s): (P,), (I, P), or dict


def ef_init(dim: int):
    """Residual for a single P-dim upload stream (e.g. the pjit train loop's
    all-reduced gradient, or the feature-based head upload)."""
    return jnp.zeros((dim,), jnp.float32)


def ef_init_stacked(num_clients: int, dim: int):
    """Per-client residuals for sample-based rounds: one (P,) vector each."""
    return jnp.zeros((num_clients, dim), jnp.float32)


def with_comm_carry(codec, body):
    """Wrap a round body into a (state, inp) scan step with the EF carry
    handled in ONE place (every driver shares this, so no copy can forget
    the residual rewrap). ``body(state, inp, ef) -> (new_state, new_ef,
    metrics)`` receives ef=None when no codec is configured; with a codec
    the step's state is CommCarry(opt=state, ef=residuals)."""
    def step(state, inp):
        if codec is None:
            new, _, metrics = body(state, inp, None)
            return new, metrics
        new, new_ef, metrics = body(state.opt, inp, state.ef)
        return CommCarry(opt=new, ef=new_ef), metrics

    return step


def ef_roundtrip(codec, x, residual, key=None, active=None):
    """One error-feedback compression step on a flat upload vector.

    Returns (enc, x_hat, new_residual). ``active`` (0/1 scalar, typically a
    participation-mask entry under vmap) freezes the residual of a client
    that did not upload this round; its x_hat is zero-masked server-side by
    the aggregation weights, so only the residual needs guarding.

    Conservation invariant (any codec): x_hat + new_residual == x + residual.
    """
    target = x + residual
    enc, x_hat = codec.roundtrip(target, key)
    new_residual = target - x_hat
    if active is not None:
        new_residual = jnp.where(active > 0, new_residual, residual)
    return enc, x_hat, new_residual

"""Upload codecs: lossy compressors for the q-statistics that cross the
client boundary (DESIGN.md §10).

Every codec implements the same three-method protocol

    encode(x, key)  -> Encoded        x: (P,) fp32 flat upload vector
    decode(enc, p)  -> x_hat (P,)     server-side reconstruction
    nbytes(p)       -> int            exact wire bytes for a P-vector (static)

(`key` may be None only for deterministic codecs — Identity, TopK;
stochastic quantizers raise without one, since reused rounding noise would
break unbiasedness.)

plus ``roundtrip(x, key) -> (enc, x_hat)`` (fused where the backend allows).
Codecs are frozen dataclasses — hashable static configuration captured in
step closures, so a scan-compiled round chain traces once per codec. All
encode/decode bodies are pure jnp with static shapes: they vmap over clients
and ride inside ``lax.scan`` without retracing.

Quantizers use *stochastic rounding*, which is unbiased:
E[decode(encode(x))] = x exactly (per-chunk absmax scaling never clips), so
the SSCA gradient estimate stays unbiased and Theorem 1's convergence
argument applies with inflated variance. Top-k is biased; pair it with
``error_feedback.ef_roundtrip`` so the bias is re-injected next round.

The uniform noise is derived from raw PRNG bits via ``uniform_from_bits`` —
the same formula the Pallas kernel (kernels/quantize.py) applies to its bits
operand, so the ``impl="pallas"`` path matches ``impl="ref"`` bit-for-bit.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

F32_BYTES = 4
IDX_BYTES = 4      # int32 coordinate per kept entry (top-k wire format)


# ---------------------------------------------------------------------------
# shared quantization math (also the oracle for kernels/quantize.py)
# ---------------------------------------------------------------------------


def uniform_from_bits(bits):
    """uint32 random bits -> Uniform[0,1) with 24-bit mantissa precision.
    Identical to the Pallas kernel's formula so ref == kernel exactly."""
    return ((bits >> jnp.uint32(8)).astype(jnp.float32)
            * jnp.float32(1.0 / (1 << 24)))


def chunk_pad(x, chunk: int):
    """(P,) -> (C, chunk) zero-padded, C = ceil(P/chunk)."""
    p = x.shape[0]
    pad = (-p) % chunk
    return jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(-1, chunk)


def stochastic_round_chunks(xc, u, qmax: int):
    """Per-chunk absmax scale + stochastic rounding. xc, u: (C, chunk).
    Returns (q int8 (C, chunk), scales fp32 (C,)). Unbiased:
    E[floor(y+u)] = y for u ~ U[0,1), and |y| <= qmax up to one ulp of the
    scale, which the safety clip absorbs. The scale is an explicit
    reciprocal-multiply (not absmax/qmax) so XLA computes the identical op
    in every compilation context — division by a constant gets
    strength-reduced to a one-ulp-different multiply only sometimes, which
    would break the exact codec == Pallas-kernel parity."""
    absmax = jnp.max(jnp.abs(xc), axis=1, keepdims=True)
    scale = absmax * jnp.float32(1.0 / qmax)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.floor(xc / safe + u), -qmax, qmax)
    return q.astype(jnp.int8), scale[:, 0]


# ---------------------------------------------------------------------------
# encoded wire formats (pytrees of arrays — scan/vmap transparent)
# ---------------------------------------------------------------------------


class DenseEncoded(NamedTuple):
    values: jnp.ndarray            # (P,) fp32


class QuantEncoded(NamedTuple):
    values: jnp.ndarray            # (C*chunk,) int8 (int4 packs at wire level)
    scales: jnp.ndarray            # (C,) fp32 per-chunk scales


class TopKEncoded(NamedTuple):
    values: jnp.ndarray            # (k,) fp32 kept entries
    indices: jnp.ndarray           # (k,) int32 coordinates


class ChainEncoded(NamedTuple):
    indices: jnp.ndarray           # (k,) int32 coordinates
    inner: QuantEncoded            # quantized kept values


@runtime_checkable
class Codec(Protocol):
    def encode(self, x, key=None): ...
    def decode(self, enc, p: int): ...
    def nbytes(self, p: int) -> int: ...
    def roundtrip(self, x, key=None): ...


class _CodecBase:
    def roundtrip(self, x, key=None):
        """encode + decode in one call; backends may fuse (see
        StochasticQuantizer's pallas path)."""
        enc = self.encode(x, key)
        return enc, self.decode(enc, x.shape[0])


@dataclass(frozen=True)
class Identity(_CodecBase):
    """Dense fp32 passthrough — the uncompressed baseline, and the codec that
    makes `codec=` wiring exactly equal to the no-codec path."""

    def encode(self, x, key=None):
        return DenseEncoded(values=x)

    def decode(self, enc, p: int):
        return enc.values

    def nbytes(self, p: int) -> int:
        return F32_BYTES * p


@dataclass(frozen=True)
class StochasticQuantizer(_CodecBase):
    """Unbiased b-bit quantizer with per-chunk fp32 absmax scales.

    bits=8 -> levels [-127, 127] (1 byte/entry on the wire); bits=4 ->
    [-7, 7] (half a byte — the simulation stores int8 and the accounting
    charges bits/8, packing being a wire-format detail). impl="pallas" runs
    the fused quantize-dequantize kernel (kernels/quantize.py) on the padded
    chunks; it consumes the same PRNG bits as the ref path, so both impls
    produce identical wire values.
    """
    bits: int = 8
    chunk: int = 256
    impl: str = "ref"              # ref | pallas
    interpret: bool = False        # pallas interpret mode (CPU testing)

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1

    def _bits(self, key, num_chunks: int):
        if key is None:
            raise ValueError(
                "StochasticQuantizer needs a PRNG key: rounding noise must "
                "be fresh per encode or E[decode(encode(x))] = x fails "
                "(deterministic codecs like Identity/TopK accept key=None)")
        return jax.random.bits(key, (num_chunks, self.chunk), jnp.uint32)

    def encode(self, x, key=None):
        return self.roundtrip(x, key)[0]

    def roundtrip(self, x, key=None):
        p = x.shape[0]
        xc = chunk_pad(x, self.chunk)
        bits = self._bits(key, xc.shape[0])
        if self.impl == "pallas":
            from repro.kernels.quantize import stochastic_quantize_pallas
            v, s, xhat = stochastic_quantize_pallas(
                x, self.qmax, self.chunk, bits=bits.reshape(-1),
                interpret=self.interpret)
            return QuantEncoded(values=v, scales=s), xhat[:p]
        q, scales = stochastic_round_chunks(xc, uniform_from_bits(bits),
                                            self.qmax)
        enc = QuantEncoded(values=q.reshape(-1), scales=scales)
        return enc, self.decode(enc, p)

    def decode(self, enc, p: int):
        xc = (enc.values.astype(jnp.float32).reshape(-1, self.chunk)
              * enc.scales[:, None])
        return xc.reshape(-1)[:p]

    def nbytes(self, p: int) -> int:
        num_chunks = -(-p // self.chunk)
        return num_chunks * F32_BYTES + math.ceil(p * self.bits / 8)


@dataclass(frozen=True)
class TopK(_CodecBase):
    """Magnitude top-k sparsification: keep k = max(1, round(frac·P)) entries
    as (fp32 value, int32 index) pairs. Biased (E[decode] != x) — always run
    it behind error feedback; frac=1 recovers the dense vector exactly."""
    frac: float = 0.01

    def k(self, p: int) -> int:
        return max(1, min(p, int(round(self.frac * p))))

    def encode(self, x, key=None):
        _, idx = jax.lax.top_k(jnp.abs(x), self.k(x.shape[0]))
        idx = idx.astype(jnp.int32)
        return TopKEncoded(values=jnp.take(x, idx), indices=idx)

    def decode(self, enc, p: int):
        return (jnp.zeros((p,), jnp.float32)
                .at[enc.indices].set(enc.values.astype(jnp.float32)))

    def nbytes(self, p: int) -> int:
        return self.k(p) * (F32_BYTES + IDX_BYTES)


@dataclass(frozen=True)
class Chain(_CodecBase):
    """Composed codec: top-k sparsify, then quantize the kept values — the
    protocol composes, so sparsification's (k,) vector is just another
    upload for the quantizer."""
    sparse: TopK = field(default_factory=TopK)
    quant: StochasticQuantizer = field(default_factory=StochasticQuantizer)

    def encode(self, x, key=None):
        s = self.sparse.encode(x)
        return ChainEncoded(indices=s.indices,
                            inner=self.quant.encode(s.values, key))

    def decode(self, enc, p: int):
        vals = self.quant.decode(enc.inner, self.sparse.k(p))
        return jnp.zeros((p,), jnp.float32).at[enc.indices].set(vals)

    def nbytes(self, p: int) -> int:
        k = self.sparse.k(p)
        return k * IDX_BYTES + self.quant.nbytes(k)


def make_codec(name, topk_frac: float = 0.01, chunk: int = 256,
               impl: str = "ref"):
    """CLI-name -> codec instance; "none"/None -> None (dense fp32 path)."""
    if name is None or name == "none":
        return None
    if name == "identity":
        codec = Identity()
    elif name == "int8":
        codec = StochasticQuantizer(bits=8, chunk=chunk, impl=impl)
    elif name == "int4":
        codec = StochasticQuantizer(bits=4, chunk=chunk, impl=impl)
    elif name == "topk":
        codec = TopK(frac=topk_frac)
    elif name == "topk8":
        codec = Chain(sparse=TopK(frac=topk_frac),
                      quant=StochasticQuantizer(bits=8, chunk=chunk,
                                                impl=impl))
    else:
        raise ValueError(f"unknown codec {name!r} "
                         "(choose none|identity|int8|int4|topk|topk8)")
    # remember the CLI name for run manifests (obs/sinks.run_manifest);
    # frozen dataclass, so set through object.__setattr__
    object.__setattr__(codec, "name", name)
    return codec


# ---------------------------------------------------------------------------
# pytree <-> flat-vector adapters (static shapes; jit/vmap/scan safe)
# ---------------------------------------------------------------------------


def tree_flat_dim(tree, stacked: bool = False) -> int:
    """Total scalar count of a pytree; with stacked=True, per-client count of
    a tree whose leaves carry a leading client axis."""
    leaves = jax.tree.leaves(tree)
    total = sum(l.size for l in leaves)
    return total // leaves[0].shape[0] if stacked else total


def flatten_tree(tree):
    """pytree -> ((P,) fp32 flat vector, unflatten) with P static."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])

    def unflatten(f):
        out, o = [], 0
        for s, dt in zip(shapes, dtypes):
            n = math.prod(s)
            out.append(f[o:o + n].reshape(s).astype(dt))
            o += n
        return jax.tree.unflatten(treedef, out)

    return flat, unflatten


def flatten_stacked(tree):
    """pytree of (I, ...) leaves -> ((I, P) fp32, unflatten): one flat upload
    vector per client, so codecs vmap over the client axis."""
    leaves, treedef = jax.tree.flatten(tree)
    num = leaves[0].shape[0]
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    flat = jnp.concatenate(
        [l.reshape(num, -1).astype(jnp.float32) for l in leaves], axis=1)

    def unflatten(f):
        out, o = [], 0
        for s, dt in zip(shapes, dtypes):
            n = math.prod(s[1:])
            out.append(f[:, o:o + n].reshape(s).astype(dt))
            o += n
        return jax.tree.unflatten(treedef, out)

    return flat, unflatten

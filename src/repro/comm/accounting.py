"""Exact bytes-on-wire bookkeeping for federated rounds (DESIGN.md §10).

Subsumes and extends the Fig.-3 per-round float counters that used to live
inline in ``core/fed.py`` (``comm_load_per_round`` moved here; ``fed``
re-exports it unchanged). The byte-level functions know about codecs: a
compressed q-upload is charged its exact wire size (``codec.nbytes``),
downlink broadcasts and the feature-based h-exchange stay dense fp32 unless
stated otherwise. ``CommLedger`` accumulates per-round dicts so drivers and
benchmarks report totals and the compression ratio measured, not asserted.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence

F32_BYTES = 4


def vector_nbytes(p: int, codec=None) -> int:
    """Wire bytes of one P-dim upload: dense fp32, or the codec's format."""
    return F32_BYTES * p if codec is None else codec.nbytes(p)


def compression_ratio(codec, p: int) -> float:
    """Dense-fp32 bytes over codec bytes for a P-vector (>= 1 is smaller)."""
    return (F32_BYTES * p) / vector_nbytes(p, codec)


def sample_round_bytes(d: int, num_clients: int, codec=None,
                       participation: Optional[int] = None,
                       with_value: bool = False,
                       num_constraints: int = 0) -> Dict[str, int]:
    """Bytes for one Algorithm-1/2 round: S of I clients upload their
    (possibly compressed) q-gradient (+ fp32 value scalars for the
    constrained variants), the server broadcasts dense ω to all I."""
    s = num_clients if participation is None else min(participation,
                                                      num_clients)
    per_client = ((1 + num_constraints) * vector_nbytes(d, codec)
                  + (num_constraints + (1 if with_value else 0)) * F32_BYTES)
    up = s * per_client
    down = num_clients * F32_BYTES * d
    return {"up": up, "down": down, "total": up + down}


def psum_axis_bytes(d: int, num_shards: int, with_value: bool = False,
                    num_streams: int = 1) -> int:
    """Bytes crossing the client-sharding mesh axis per round when the
    N_i/(B_i·N) aggregation of eq. (9) is realized as a `lax.psum` over D
    client shards (core/topology.py's ShardedTopology).

    Each shard contributes one pre-weighted d-dim fp32 partial sum (+ the
    fp32 value partial for the constrained variants); a ring all-reduce
    moves 2·(D−1)/D · payload per device, i.e. 2·(D−1)·payload over the
    whole axis. D = 1 costs nothing — the local topology is recovered.
    ``num_streams`` counts independent psums per round (e.g. Algorithm 2
    general runs separate objective and constraint aggregations)."""
    if num_shards <= 1:
        return 0
    payload = F32_BYTES * (d + (1 if with_value else 0))
    return 2 * (num_shards - 1) * payload * num_streams


def all_gather_axis_bytes(d_total: int, num_shards: int) -> int:
    """Bytes crossing the client mesh axis per round when the feature-based
    step-4 h-broadcast is realized as a tiled `lax.all_gather` over D client
    shards (core/topology.py's ShardedTopology.feature_sum).

    d_total is the FULL gathered element count (I·B·J for the h-exchange);
    a ring all-gather moves (D−1) chunks of d_total/D elements per device,
    i.e. (D−1)·d_total fp32 over the whole axis. D = 1 costs nothing — the
    local topology is recovered."""
    if num_shards <= 1:
        return 0
    return (num_shards - 1) * F32_BYTES * d_total


def feature_round_bytes(d_head: int, d_blocks: Sequence[int], batch_size: int,
                        h_dim: int, num_clients: int,
                        codec=None) -> Dict[str, int]:
    """Bytes for one Algorithm-3/4 round: dense h-exchange between the I
    clients (B·H floats from each client to each other client), compressed
    q_{f,0,0} head upload and q_{f,0,i} block uploads, dense broadcast."""
    h_x = F32_BYTES * batch_size * h_dim * num_clients * (num_clients - 1)
    up = (vector_nbytes(d_head, codec)
          + sum(vector_nbytes(db, codec) for db in d_blocks))
    down = num_clients * F32_BYTES * (d_head + sum(d_blocks))
    return {"up": up, "down": down, "h_exchange": h_x,
            "total": up + down + h_x}


@dataclass
class CommLedger:
    """Running per-round byte totals; feed it the dicts above."""
    rounds: int = 0
    totals: Dict[str, int] = field(default_factory=dict)

    def add(self, round_bytes: Dict[str, int], n: int = 1) -> "CommLedger":
        self.rounds += n
        for k, v in round_bytes.items():
            self.totals[k] = self.totals.get(k, 0) + n * v
        return self

    def summary(self) -> Dict[str, float]:
        out = {"rounds": self.rounds, **self.totals}
        if self.rounds:
            out.update({f"{k}_per_round": v / self.rounds
                        for k, v in self.totals.items()})
        return out

    def as_row(self) -> Dict[str, float]:
        """The ledger as an obs sink row (``kind="comm"``), so drivers can
        interleave wire totals with the streamed round/eval/span rows."""
        return {"kind": "comm", **self.summary()}

    def emit(self, stream) -> "CommLedger":
        """Emit :meth:`as_row` through a `MetricStream` (or any object with
        ``emit_event``)."""
        stream.emit_event(self.as_row())
        return self


# ---------------------------------------------------------------------------
# Fig. 3 float counters (moved verbatim from core/fed.py; fed re-exports)
# ---------------------------------------------------------------------------


def comm_load_per_round(mode: str, d: int, d_blocks: Sequence[int] = (),
                        batch_size: int = 0, h_dim: int = 0,
                        num_clients: int = 0, num_constraints: int = 0):
    """Floats communicated per round (paper's per-round load accounting).

    sample-based (Alg 1/2): each client uploads d (+M·(1+d)); server broadcasts d.
    feature-based (Alg 3/4): h-exchange B·H·I·(I-1) between clients, block
    gradients d_i up, broadcast d down.
    """
    m = num_constraints
    if mode == "sample":
        up = num_clients * (d + m * (1 + d))
        down = num_clients * d
        return {"up": up, "down": down, "total": up + down}
    h_x = batch_size * h_dim * num_clients * (num_clients - 1) * (1 + m)
    up = sum(d_blocks) * (1 + m) + (d - sum(d_blocks)) * (1 + m) + m * num_clients
    down = num_clients * d
    return {"up": up, "down": down, "h_exchange": h_x,
            "total": up + down + h_x}

"""Optional privacy mechanisms layered on the paper's model aggregation.

The paper (§III-A.2 etc.) notes that when the q-statistics system of
equations is solvable, *extra* mechanisms are needed: homomorphic encryption
(out of scope — no crypto here), secret sharing, or differential privacy.
We implement the Gaussian mechanism on client uploads:

  q̃_i = clip(q_i, C) + N(0, σ²C²I)

which, per round, gives (ε, δ)-DP for the standard calibration
σ = sqrt(2 ln(1.25/δ)) / ε against the B-sum sensitivity C (per-client
add/remove adjacency; composition across rounds via the usual accountants —
we report the per-round ε only). The SSCA aggregate stays *unbiased*
(the noise is zero-mean), so Theorem 1's convergence argument applies to the
noised estimates with inflated variance; tests check convergence survives
moderate σ and that the noised upload no longer reveals the exact q.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class DPConfig(NamedTuple):
    clip_norm: float = 1.0       # C: l2 clip of each client's q upload
    epsilon: float = 8.0         # per-round ε
    delta: float = 1e-5


def noise_multiplier(dp: DPConfig) -> float:
    """Gaussian-mechanism σ/C for (ε, δ)-DP (per round)."""
    return math.sqrt(2.0 * math.log(1.25 / dp.delta)) / dp.epsilon


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def privatize_upload(q_tree, key, dp: DPConfig):
    """Clip a single client's q-statistic pytree to C and add N(0, σ²C²)."""
    norm = _global_norm(q_tree)
    scale = jnp.minimum(1.0, dp.clip_norm / jnp.maximum(norm, 1e-12))
    sigma = noise_multiplier(dp) * dp.clip_norm
    leaves, treedef = jax.tree.flatten(q_tree)
    keys = jax.random.split(key, len(leaves))
    noised = [l.astype(jnp.float32) * scale
              + sigma * jax.random.normal(k, l.shape)
              for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, noised)


def dp_sample_round(per_sample_loss, params, data, key, batch_size: int,
                    dp: DPConfig):
    """fed.sample_round with per-client clipping + Gaussian noise on uploads.

    Clipping is applied to the client's *mean* gradient (q_i / B) so C is a
    per-example-scale constant; aggregation weights are N_i/N as in (3).
    """
    from repro.core import fed
    idx = fed.sample_batches(data, key, batch_size)
    n_total = data.total.astype(jnp.float32)

    def client(feat_i, lab_i, idx_i, k):
        zb = jnp.take(feat_i, idx_i, axis=0)
        yb = jnp.take(lab_i, idx_i, axis=0)
        g = jax.grad(lambda p: jnp.mean(per_sample_loss(p, zb, yb)))(params)
        return privatize_upload(g, k, dp)

    keys = jax.random.split(jax.random.fold_in(key, 1), data.num_clients)
    q = jax.vmap(client)(data.features, data.labels, idx, keys)
    w = data.counts.astype(jnp.float32) / n_total
    grad_est = jax.tree.map(lambda u: jnp.tensordot(w, u, axes=1), q)
    return grad_est, q

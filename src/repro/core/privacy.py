"""Differential privacy on the q-uploads: calibration, the clip→noise
stage, and cross-round RDP accounting (DESIGN.md §15).

The paper (§III-A.2 etc.) notes that when the q-statistics system of
equations is solvable, *extra* mechanisms are needed: homomorphic encryption
(out of scope — no crypto here), secret sharing, or differential privacy.
We implement the Gaussian mechanism at the client boundary: each client's
per-round release is its B_i-mean q-statistic, clipped to ℓ2 norm C and
noised,

  m̃_i = clip(q_i / B_i, C) + N(0, σ²C²·I_P),

re-scaled to the B_i-sum so the eq.-(9) aggregation weights are untouched.
The stage runs INSIDE ``Topology.weighted_sum`` — after client compute,
BEFORE codec encode — so the wire format, the bytes-on-wire accounting, and
the error-feedback residual all see the already-privatized upload (what a
deployment's server would see; the EF residual never stores raw signal).
The sharded engine adds the noise per shard, so the psum aggregates
already-noised contributions. Drive it with ``dp=DPConfig(...)`` on
``fed.sample_round`` / ``fed.cohort_round`` / ``fed.feature_round`` and on
every ``core.algorithms`` driver.

Calibration is the ANALYTIC Gaussian mechanism (Balle & Wang 2018): the
smallest σ satisfying the exact Gaussian-CDF (ε, δ) condition, found by
binary search. The classical σ = sqrt(2 ln(1.25/δ))/ε closed form is only a
valid (ε, δ)-DP calibration for ε < 1 — this module's historical default
ε = 8 sat outside its regime — and is strictly looser than the analytic σ
everywhere (kept as :func:`classical_noise_multiplier` for the comparison
tests).

Cross-round accounting composes in Rényi DP (Abadi et al. 2016 moments
accountant; Mironov 2017): one subsampled-Gaussian release at rate
q = S/I has a closed-form RDP(α) bound per order α, RDP composes LINEARLY
over the K scanned rounds, and ε(δ) = min_α [K·RDP(α) + log(1/δ)/(α−1)].
The linearity is what makes ε-so-far streamable from inside a ``lax.scan``:
:func:`make_eps_fn` bakes the per-round RDP vector into the step closure as
a constant and the round's global 1-based index ``RoundInputs.t`` does the
rest — no table indexed by the horizon.

Accounting caveats (documented, conservative direction where they bend):
the adjacency is client-level (add/remove one client's whole shard — each
client's release is what crosses the trust boundary); per-client noise
makes the central aggregate carry S independent noise draws where the
accountant only assumes one, so the reported ε is conservative by ~√S for
the aggregate observer; and the cohort engine's uniform-WITHOUT-replacement
draw is accounted with the Poisson-subsampling RDP bound (the standard
practice — the two samplings agree at S ≪ I). The scalar loss stream of
``with_value=True`` rounds is NOT privatized (gradient statistics only).
"""
from __future__ import annotations

import math
import warnings
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class DPConfig(NamedTuple):
    """Clip+noise configuration for the client-boundary DP stage.

    ``noise_multiplier`` overrides the analytic (ε, δ) calibration with an
    explicit σ/C (e.g. to sweep noise directly in benchmarks); when None,
    σ/C is calibrated from (epsilon, delta) per release by
    :func:`analytic_gaussian_sigma`."""
    clip_norm: float = 1.0       # C: ℓ2 clip of each client's mean q upload
    epsilon: float = 8.0         # per-release ε target (see accountant fns
    delta: float = 1e-5          #   for the composed cross-round ε)
    noise_multiplier: Optional[float] = None


# ---------------------------------------------------------------------------
# Gaussian-mechanism calibration
# ---------------------------------------------------------------------------


def classical_noise_multiplier(epsilon: float, delta: float) -> float:
    """σ/C of the classical Gaussian mechanism, sqrt(2 ln(1.25/δ))/ε — a
    valid (ε, δ)-DP calibration ONLY for ε < 1 (Dwork & Roth Thm. A.1), and
    looser than the analytic calibration everywhere it is valid. Kept for
    the reduction tests; use :func:`analytic_gaussian_sigma` to calibrate."""
    return math.sqrt(2.0 * math.log(1.25 / delta)) / epsilon


def _phi(x: float) -> float:
    """Standard normal CDF via erf (no scipy dependency)."""
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


def gaussian_mechanism_delta(epsilon: float, sigma: float,
                             sensitivity: float = 1.0) -> float:
    """EXACT δ achieved by N(0, σ²) noise on a Δ-sensitive query at privacy
    parameter ε (Balle & Wang 2018, Thm. 8):

      δ(σ) = Φ(Δ/2σ − εσ/Δ) − e^ε · Φ(−Δ/2σ − εσ/Δ)

    Decreasing in σ (from 1 at σ→0 to 0 at σ→∞), which is what the binary
    search in :func:`analytic_gaussian_sigma` inverts."""
    a = sensitivity / (2.0 * sigma)
    b = epsilon * sigma / sensitivity
    return _phi(a - b) - math.exp(epsilon) * _phi(-a - b)


def analytic_gaussian_sigma(epsilon: float, delta: float,
                            sensitivity: float = 1.0,
                            iters: int = 200) -> float:
    """Smallest σ with ``gaussian_mechanism_delta(ε, σ, Δ) <= δ`` — the
    analytic Gaussian mechanism calibration, valid for EVERY ε > 0 (binary
    search on the exact CDF condition; δ(σ) is monotone decreasing)."""
    if epsilon <= 0 or not 0 < delta < 1:
        raise ValueError(f"need epsilon > 0 and 0 < delta < 1, got "
                         f"({epsilon}, {delta})")
    lo = 1e-8 * sensitivity
    hi = max(classical_noise_multiplier(epsilon, delta) * sensitivity,
             sensitivity)
    while gaussian_mechanism_delta(epsilon, hi, sensitivity) > delta:
        hi *= 2.0
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if gaussian_mechanism_delta(epsilon, mid, sensitivity) > delta:
            lo = mid
        else:
            hi = mid
    return hi


def noise_multiplier(dp: DPConfig) -> float:
    """σ/C of one release under ``dp``: the explicit override if set, else
    the analytic Gaussian calibration of (ε, δ) at unit sensitivity."""
    if dp.noise_multiplier is not None:
        return float(dp.noise_multiplier)
    return analytic_gaussian_sigma(dp.epsilon, dp.delta, 1.0)


# ---------------------------------------------------------------------------
# cross-round accounting: subsampled-Gaussian RDP, composed over the scan
# ---------------------------------------------------------------------------

# integer Rényi orders — dense where the minimum usually lands, sparse tail
# for very-many-round compositions
DEFAULT_ORDERS: Sequence[int] = tuple(range(2, 65)) + (80, 96, 128, 192, 256)


def rdp_per_round(sample_rate: float, noise_mult: float,
                  orders: Sequence[int] = DEFAULT_ORDERS) -> np.ndarray:
    """RDP(α) of ONE subsampled Gaussian release, per order.

    Full participation (q = 1): the Gaussian mechanism's exact
    RDP(α) = α/(2σ²). Subsampled at rate q < 1 (integer α — Abadi et al.
    2016 Lemma 3 / Mironov et al. 2019):

      RDP(α) = log( Σ_{k=0}^{α} C(α,k) (1−q)^{α−k} q^k e^{k(k−1)/2σ²} ) / (α−1)

    evaluated in log-space (lgamma binomials + log-sum-exp) so large α and
    small σ don't overflow. Host-side numpy — these are trace-time
    constants, never traced."""
    q, s = float(sample_rate), float(noise_mult)
    if not 0.0 < q <= 1.0:
        raise ValueError(f"sample_rate must be in (0, 1], got {q}")
    if s <= 0.0:
        raise ValueError(f"noise_mult must be > 0, got {s}")
    out = []
    for a in orders:
        a = int(a)
        if a < 2:
            raise ValueError(f"orders must be integers >= 2, got {a}")
        if q == 1.0:
            out.append(a / (2.0 * s * s))
            continue
        log_terms = [
            math.lgamma(a + 1) - math.lgamma(k + 1) - math.lgamma(a - k + 1)
            + (a - k) * math.log1p(-q) + k * math.log(q)
            + k * (k - 1) / (2.0 * s * s)
            for k in range(a + 1)
        ]
        m = max(log_terms)
        log_sum = m + math.log(sum(math.exp(t - m) for t in log_terms))
        out.append(log_sum / (a - 1))
    return np.asarray(out, np.float64)


def eps_from_rdp(rdp_total, delta: float,
                 orders: Sequence[int] = DEFAULT_ORDERS):
    """(ε, best α) from composed RDP: ε = min_α [RDP(α) + log(1/δ)/(α−1)]
    (the standard RDP→(ε, δ) conversion)."""
    ords = np.asarray(orders, np.float64)
    eps = np.asarray(rdp_total, np.float64) + math.log(1.0 / delta) / (
        ords - 1.0)
    i = int(np.argmin(eps))
    return float(eps[i]), int(ords[i])


def accountant_epsilon(noise_mult: float, sample_rate: float, steps: int,
                       delta: float,
                       orders: Sequence[int] = DEFAULT_ORDERS) -> float:
    """ε(δ) after ``steps`` composed subsampled-Gaussian releases at rate
    ``sample_rate`` and noise σ/C = ``noise_mult`` — RDP composes linearly,
    then converts once."""
    rdp = rdp_per_round(sample_rate, noise_mult, orders)
    return eps_from_rdp(steps * rdp, delta, orders)[0]


def epsilon_schedule(dp: DPConfig, sample_rate: float, rounds: int,
                     releases_per_round: int = 1,
                     orders: Sequence[int] = DEFAULT_ORDERS) -> np.ndarray:
    """ε-so-far after each of ``rounds`` rounds (host-side; the in-graph
    metric of :func:`make_eps_fn` matches this array entry for entry)."""
    rdp = rdp_per_round(sample_rate, noise_multiplier(dp),
                        orders) * releases_per_round
    return np.asarray([eps_from_rdp(t * rdp, dp.delta, orders)[0]
                       for t in range(1, rounds + 1)])


def make_eps_fn(dp: DPConfig, sample_rate: float = 1.0,
                releases_per_round: int = 1,
                orders: Sequence[int] = DEFAULT_ORDERS):
    """t (global 1-based round, ``RoundInputs.t``) → ε-so-far, as a jnp
    closure usable INSIDE the scanned step: RDP composition is linear in t,
    so ε(t) = min_α [t·rdp(α) + log(1/δ)/(α−1)] with the per-round RDP and
    conversion vectors baked in as small constants — any horizon, no
    horizon-sized table."""
    rdp = rdp_per_round(sample_rate, noise_multiplier(dp),
                        orders) * releases_per_round
    conv = math.log(1.0 / dp.delta) / (np.asarray(orders, np.float64) - 1.0)
    rdp_c = jnp.asarray(rdp, jnp.float32)
    conv_c = jnp.asarray(conv, jnp.float32)

    def eps_fn(t):
        return jnp.min(jnp.asarray(t, jnp.float32) * rdp_c + conv_c)

    return eps_fn


def manifest_info(dp: DPConfig, sample_rate: float = 1.0,
                  rounds: Optional[int] = None,
                  releases_per_round: int = 1) -> dict:
    """The run-manifest record of a DP run: configuration, calibrated σ/C,
    and (when the horizon is known) the accountant's composed ε at the end
    of the run — so a metrics file states its own privacy budget."""
    nm = noise_multiplier(dp)
    info = {"clip_norm": dp.clip_norm, "epsilon": dp.epsilon,
            "delta": dp.delta, "noise_multiplier": nm,
            "sample_rate": sample_rate,
            "releases_per_round": releases_per_round,
            "accountant": "subsampled-gaussian-rdp"}
    if rounds is not None:
        info["rounds"] = rounds
        info["epsilon_total"] = accountant_epsilon(
            nm, sample_rate, rounds * releases_per_round, dp.delta)
    return info


# ---------------------------------------------------------------------------
# the clip→noise stage (called by core.topology at the client boundary)
# ---------------------------------------------------------------------------


def clip_and_noise(flat, keys, dp: DPConfig, scale=None):
    """The per-client clip→noise stage on stacked flat uploads.

    ``flat`` is (n, P) — one row per client, holding the client's upload in
    SUM scale (B_i-summed q-statistics); ``scale`` (n,) converts each row to
    the clipped unit (1/B_i for batch sums; None = rows are already means).
    Each row is scaled to its mean m_i, clipped to ``dp.clip_norm``, noised
    with N(0, σ²C²) at the calibrated σ/C, and scaled back, so aggregation
    weights downstream are untouched.

    Returns ``(privatized (n, P), stats)`` with per-client
    ``stats["clipped"]`` (0/1 — did the clip bind) and ``stats["noise_sq"]``
    (Σ noise², for the streamed noise-norm metric). Pure vmapped jnp: the
    identical bits run under the local vmap engine and inside each
    shard_map shard."""
    sigma = noise_multiplier(dp) * dp.clip_norm
    n = flat.shape[0]
    if scale is None:
        scale = jnp.ones((n,), jnp.float32)

    def one(x, k, s):
        m = x.astype(jnp.float32) * s
        nrm = jnp.sqrt(jnp.sum(jnp.square(m)))
        m = m * jnp.minimum(1.0, dp.clip_norm / jnp.maximum(nrm, 1e-12))
        noise = sigma * jax.random.normal(k, m.shape)
        return ((m + noise) / s,
                (nrm > dp.clip_norm).astype(jnp.float32),
                jnp.sum(jnp.square(noise)))

    priv, clipped, noise_sq = jax.vmap(one)(flat, keys,
                                            scale.astype(jnp.float32))
    return priv, {"clipped": clipped, "noise_sq": noise_sq}


def privatize_flat(flat, key, dp: DPConfig):
    """Single-stream convenience for one (P,) mean-scale upload (the pjit
    train loop's all-reduced gradient): clip to C, add N(0, σ²C²).
    Returns (privatized (P,), {"clipped": scalar, "noise_sq": scalar})."""
    priv, st = clip_and_noise(flat[None], key[None], dp)
    return priv[0], {"clipped": st["clipped"][0],
                     "noise_sq": st["noise_sq"][0]}


def privatize_upload(q_tree, key, dp: DPConfig):
    """Clip a single client's q-statistic pytree to C and add N(0, σ²C²)
    per leaf (kept for API compatibility; the round-level path is the
    ``dp=`` argument of fed.sample_round / cohort_round / feature_round,
    which privatizes the FLAT per-client upload inside the topology)."""
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(q_tree)))
    scale = jnp.minimum(1.0, dp.clip_norm / jnp.maximum(norm, 1e-12))
    sigma = noise_multiplier(dp) * dp.clip_norm
    leaves, treedef = jax.tree.flatten(q_tree)
    keys = jax.random.split(key, len(leaves))
    noised = [l.astype(jnp.float32) * scale
              + sigma * jax.random.normal(k, l.shape)
              for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, noised)


# ---------------------------------------------------------------------------
# deprecated entry point (pre-dp= API)
# ---------------------------------------------------------------------------


def dp_sample_round(per_sample_loss, params, data, key, batch_size: int,
                    dp: DPConfig):
    """DEPRECATED: use ``fed.sample_round(..., dp=dp)`` (the codec-, EF-,
    topology-, and cohort-composable path; same per-client clip+noise on
    the mean gradient, same N_i/N effective weighting).

    This shim delegates to it — which also fixes the historical
    ragged-client bias: the old inline client closure took ``jnp.take``
    batches with no ``batch_mask``, so padded rows of clients with
    N_i < B entered the clipped mean. Returns (grad_est, per-client
    privatized q sums) to preserve the historical 2-tuple shape."""
    warnings.warn(
        "[FLT004] repro.core.privacy.dp_sample_round is deprecated; use "
        "repro.core.fed.sample_round(..., dp=dp) — the dp= path composes "
        "with codec/EF/topology/cohort and fixes the ragged-client bias "
        "(flagged by `python -m repro.analysis`)",
        DeprecationWarning, stacklevel=2)
    from repro.core import fed
    grad_est, _, up = fed.sample_round(per_sample_loss, params, data, key,
                                       batch_size, dp=dp)
    return grad_est, up["q_grad_sums"]

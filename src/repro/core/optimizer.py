"""SSCA as a composable optimizer over arbitrary param pytrees.

This is the integration point for the model zoo: `ssca_init` / `ssca_step`
behave like an optax-style (state, grad) -> state optimizer, implementing the
paper's Algorithm 1/3 example updates exactly (eqs. (8)-(10)/(22)-(24), with
the λ‖ω‖² regularizer folded into the same buffer — see DESIGN.md §2).

`ssca_constrained_step` implements the Algorithm 2/4 example for the paper's
constrained formulation (40): min ‖ω‖² s.t. mean-loss <= U, via Lemma 1.

`momentum_sgd_form_*` implements eqs. (11)-(12) — the *identical* sequence as
momentum SGD with momentum v^t and stepsize γ^t (Remark 2); tested to match
ssca_step bit-for-bit-ish in tests/test_equivalence.py.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import schedules
from repro.core.solvers import lemma1_nu, solve_constrained_single
from repro.obs import trace as obs_trace
from repro.core.surrogate import (QuadSurrogate, init_surrogate,
                                  update_surrogate)
from repro.core.tree import tree_axpy, tree_dot, tree_l2sq, tree_zeros_like


class SSCAState(NamedTuple):
    params: object
    g: object                 # linear surrogate buffer (eq. 9, λ folded)
    t: jnp.ndarray            # 1-based round counter


class SSCAConstrainedState(NamedTuple):
    params: object
    cons: QuadSurrogate       # constraint surrogate (g buffer + scalar d)
    t: jnp.ndarray
    nu: jnp.ndarray           # last dual value (diagnostic)
    slack: jnp.ndarray        # last slack (Theorem 2: -> 0)


def _sched(fl, t, rho_t=None, gamma_t=None):
    # the paper's examples choose ρ^(1) = 1 (§III-A, before eq. (11)): the
    # t=1 surrogate is then a pure batch estimate, independent of the zero init.
    # Callers may pass precomputed per-round (rho_t, gamma_t) — the scan-round
    # driver (core/rounds.py) threads them as scan inputs so K compiled rounds
    # never recompute the power-law schedule from the carried t.
    if rho_t is None:
        rho_t = jnp.where(t == 1, 1.0, schedules.rho(t, fl.a1, fl.alpha_rho))
    if gamma_t is None:
        gamma_t = schedules.gamma(t, fl.a2, fl.alpha_gamma)
    return rho_t, gamma_t


# ---------------------------------------------------------------------------
# unconstrained (Algorithm 1 / 3 example)
# ---------------------------------------------------------------------------


def ssca_init(params) -> SSCAState:
    return SSCAState(params=params, g=tree_zeros_like(params, jnp.float32),
                     t=jnp.ones((), jnp.int32))


@obs_trace.scoped("surrogate-solve")
def ssca_step(state: SSCAState, grad, fl, rho_t=None, gamma_t=None) -> SSCAState:
    """grad: aggregated mini-batch gradient estimate of the *data* loss F
    (the λ‖ω‖² regularizer is injected here, not in grad)."""
    rho_t, gamma_t = _sched(fl, state.t, rho_t, gamma_t)
    lam, tau = fl.l2_lambda, fl.tau
    # eq. (9) with 2λω folded (eq. 35): inj = ∇F̂ + 2λω - 2τω
    g = jax.tree.map(
        lambda b, gr, w: (1 - rho_t) * b
        + rho_t * (gr.astype(jnp.float32) + (2 * lam - 2 * tau) * w.astype(jnp.float32)),
        state.g, grad, state.params)
    # eq. (10): ω̄ = -g/(2τ); eq. (5): ω ← (1-γ)ω + γω̄
    params = jax.tree.map(
        lambda w, b: ((1 - gamma_t) * w.astype(jnp.float32)
                      + gamma_t * (-b / (2 * tau))).astype(w.dtype),
        state.params, g)
    return SSCAState(params=params, g=g, t=state.t + 1)


# ---------------------------------------------------------------------------
# momentum-SGD form (Remark 2, eqs. (11)-(12)) — same iterates as ssca_step
# ---------------------------------------------------------------------------


class MomentumForm(NamedTuple):
    params: object
    v: object
    t: jnp.ndarray
    gamma_prev: jnp.ndarray


def momentum_form_init(params) -> MomentumForm:
    return MomentumForm(params=params, v=tree_zeros_like(params, jnp.float32),
                        t=jnp.ones((), jnp.int32),
                        gamma_prev=jnp.zeros((), jnp.float32))


@obs_trace.scoped("surrogate-solve")
def momentum_form_step(state: MomentumForm, grad, fl, rho_t=None,
                       gamma_t=None) -> MomentumForm:
    """v^t = (1-ρ^t)(1-γ^(t-1)) v^(t-1) + (ρ^t/2τ) ĝ^t;  ω ← ω - γ^t v^t.

    ĝ here is the gradient of the *full* objective incl. the regularizer
    (∇F̂ + 2λω); with ρ^(1)=1 the iterates equal ssca_step exactly.
    """
    rho_t, gamma_t = _sched(fl, state.t, rho_t, gamma_t)
    full_grad = jax.tree.map(
        lambda gr, w: gr.astype(jnp.float32) + 2 * fl.l2_lambda * w.astype(jnp.float32),
        grad, state.params)
    v = jax.tree.map(
        lambda vv, gg: (1 - rho_t) * (1 - state.gamma_prev) * vv
        + rho_t / (2 * fl.tau) * gg,
        state.v, full_grad)
    params = jax.tree.map(
        lambda w, vv: (w.astype(jnp.float32) - gamma_t * vv).astype(w.dtype),
        state.params, v)
    return MomentumForm(params=params, v=v, t=state.t + 1, gamma_prev=gamma_t)


# ---------------------------------------------------------------------------
# constrained (Algorithm 2 / 4 example; formulation (40) via Lemma 1)
# ---------------------------------------------------------------------------


def ssca_constrained_init(params) -> SSCAConstrainedState:
    return SSCAConstrainedState(
        params=params, cons=init_surrogate(params), t=jnp.ones((), jnp.int32),
        nu=jnp.zeros(()), slack=jnp.zeros(()))


@obs_trace.scoped("surrogate-solve")
def ssca_constrained_step(state: SSCAConstrainedState, loss_grad, loss_value,
                          fl, rho_t=None, gamma_t=None) -> SSCAConstrainedState:
    """min ‖ω‖² s.t. F(ω) <= U  (eq. 40). Objective is deterministic and kept
    exact (τ0 = 1 quadratic); the loss constraint is approximated per (15)."""
    rho_t, gamma_t = _sched(fl, state.t, rho_t, gamma_t)
    cons = update_surrogate(state.cons, rho_t, state.params, loss_grad,
                            loss_value - fl.cost_limit, fl.tau)
    # Lemma 1 closed form (g0 = 0): ν* then ω̄ = -ν g1 / (2(1 + ν τ))
    b = tree_l2sq(cons.g)
    nu = lemma1_nu(b, cons.d, fl.tau, fl.penalty_c)
    t_ = 1.0 + nu * fl.tau
    params = jax.tree.map(
        lambda w, g1: ((1 - gamma_t) * w.astype(jnp.float32)
                       + gamma_t * (-(nu * g1) / (2 * t_))).astype(w.dtype),
        state.params, cons.g)
    # slack at the solution: max(F̄_1(ω̄), 0)
    gw = tree_dot(cons.g, jax.tree.map(lambda g1: -(nu * g1) / (2 * t_), cons.g))
    wsq = (nu * nu) * b / (4 * t_ * t_)
    slack = jnp.maximum(cons.d + gw + fl.tau * wsq, 0.0)
    return SSCAConstrainedState(params=params, cons=cons, t=state.t + 1,
                                nu=nu, slack=slack)


class SSCAGeneralConstrainedState(NamedTuple):
    """Full Algorithm 2/4 state: sampled objective + sampled constraint."""
    params: object
    obj_g: object             # objective linear buffer (eq. 9)
    cons: QuadSurrogate       # constraint surrogate (eqs. as in §III-B example)
    t: jnp.ndarray
    nu: jnp.ndarray
    slack: jnp.ndarray


def ssca_general_constrained_init(params) -> SSCAGeneralConstrainedState:
    return SSCAGeneralConstrainedState(
        params=params, obj_g=tree_zeros_like(params, jnp.float32),
        cons=init_surrogate(params), t=jnp.ones((), jnp.int32),
        nu=jnp.zeros(()), slack=jnp.zeros(()))


@obs_trace.scoped("surrogate-solve")
def ssca_general_constrained_step(state: SSCAGeneralConstrainedState, obj_grad,
                                  cons_grad, cons_value, fl, rho_t=None,
                                  gamma_t=None) -> SSCAGeneralConstrainedState:
    """Full Algorithm 2/4 example: both the objective and the constraint are
    sampled nonconvex losses; Problem 5/10 solved by monotone bisection."""
    rho_t, gamma_t = _sched(fl, state.t, rho_t, gamma_t)
    tau = fl.tau
    obj_g = jax.tree.map(
        lambda b, gr, w: (1 - rho_t) * b
        + rho_t * (gr.astype(jnp.float32) - 2 * tau * w.astype(jnp.float32)),
        state.obj_g, obj_grad, state.params)
    cons = update_surrogate(state.cons, rho_t, state.params, cons_grad,
                            cons_value - fl.cost_limit, tau)
    sol = solve_constrained_single(obj_g, tau, cons, tau, fl.penalty_c)
    params = tree_axpy(1 - gamma_t, state.params, gamma_t, sol.omega_bar)
    params = jax.tree.map(lambda p, w: p.astype(w.dtype), params, state.params)
    return SSCAGeneralConstrainedState(
        params=params, obj_g=obj_g, cons=cons, t=state.t + 1,
        nu=sol.nu[0], slack=sol.slack[0])

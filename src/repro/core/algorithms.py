"""Drivers for the paper's Algorithms 1-4 (faithful protocol simulation).

Each driver runs the paper's communication rounds with per-round client
mini-batch selection (PRNG-folded), the exact uploads of the paper, and the
closed-form server updates. The whole round chain is scan-compiled by
``core/rounds.py`` — a K-round run (or eval chunk) is a single XLA dispatch
with ρ^t/γ^t threaded through the scan (DESIGN.md §6).

The sample-based drivers (Algorithms 1/2) take ``participation=S`` to sample
S of I clients uniformly per round, with the unbiased I/S-reweighted
N_i/(B_i·N) aggregation of `fed.aggregation_weights`; they accept ragged
(e.g. Dirichlet-partitioned) client datasets transparently.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import fed, optimizer
from repro.core import rounds as rounds_lib
from repro.core.fed import FeatureFedData, SampleFedData
from repro.core.rounds import RunResult  # re-exported (public API since seed)


def _run(step_fn, state, key, num_rounds: int, eval_fn: Optional[Callable],
         eval_every: int, extract_params, fl=None, driver: str = "scan"):
    """Back-compat driver shim shared with baselines/local_updates: step_fn
    has the rounds.py signature step(state, RoundInputs-slice) -> (state,
    metrics). fl is only needed for the schedule inputs; steps that ignore
    rho/gamma (SGD baselines) may pass fl=None."""
    fl = fl if fl is not None else _NULL_SCHED
    return rounds_lib.run_rounds(step_fn, state, fl, key, num_rounds,
                             eval_fn=eval_fn, eval_every=eval_every,
                             extract_params=extract_params, driver=driver)


class _NullSched:
    a1 = a2 = 1.0
    alpha_rho = alpha_gamma = 1.0


_NULL_SCHED = _NullSched()


# ---------------------------------------------------------------------------
# Algorithm 1: unconstrained sample-based FL via mini-batch SSCA
# ---------------------------------------------------------------------------


def make_algorithm1_step(per_sample_loss, data: SampleFedData, fl,
                         participation: Optional[int] = None):
    """One full Algorithm-1 round as a pure (state, RoundInputs) step —
    batch selection, uploads, aggregation, surrogate recursion, update —
    suitable for lax.scan (rounds.scan_rounds) or per-round dispatch."""

    def step(state, inp):
        grad_est, val_est, _ = fed.sample_round(
            per_sample_loss, state.params, data, inp.key, fl.batch_size,
            participation=participation)
        new = optimizer.ssca_step(state, grad_est, fl,
                                  rho_t=inp.rho, gamma_t=inp.gamma)
        return new, {"loss_est": val_est}

    return step


def algorithm1(per_sample_loss, params0, data: SampleFedData, fl, rounds: int,
               key, eval_fn=None, eval_every: int = 10,
               participation: Optional[int] = None,
               driver: str = "scan") -> RunResult:
    step = make_algorithm1_step(per_sample_loss, data, fl, participation)
    state = optimizer.ssca_init(params0)
    return _run(step, state, key, rounds, eval_fn, eval_every,
                lambda s: s.params, fl=fl, driver=driver)


# ---------------------------------------------------------------------------
# Algorithm 2: constrained sample-based FL (formulation (40): min ‖ω‖², F <= U)
# ---------------------------------------------------------------------------


def make_algorithm2_step(per_sample_loss, data: SampleFedData, fl,
                         participation: Optional[int] = None):
    def step(state, inp):
        grad_est, val_est, _ = fed.sample_round(
            per_sample_loss, state.params, data, inp.key, fl.batch_size,
            with_value=True, participation=participation)
        new = optimizer.ssca_constrained_step(state, grad_est, val_est, fl,
                                              rho_t=inp.rho, gamma_t=inp.gamma)
        return new, {"loss_est": val_est, "nu": new.nu, "slack": new.slack}

    return step


def algorithm2(per_sample_loss, params0, data: SampleFedData, fl, rounds: int,
               key, eval_fn=None, eval_every: int = 10,
               participation: Optional[int] = None,
               driver: str = "scan") -> RunResult:
    step = make_algorithm2_step(per_sample_loss, data, fl, participation)
    state = optimizer.ssca_constrained_init(params0)
    return _run(step, state, key, rounds, eval_fn, eval_every,
                lambda s: s.params, fl=fl, driver=driver)


def algorithm2_general(obj_loss, cons_loss, params0, data: SampleFedData, fl,
                       rounds: int, key, eval_fn=None, eval_every: int = 10,
                       participation: Optional[int] = None,
                       driver: str = "scan") -> RunResult:
    """Full Algorithm 2: sampled nonconvex objective AND constraint."""
    def step(state, inp):
        k1, k2 = jax.random.split(inp.key)
        # ONE participant set per round: both the objective and the constraint
        # statistics are uploaded by the same S clients (faithful protocol).
        pk = jax.random.fold_in(inp.key, 0x5ca)
        og, _, _ = fed.sample_round(obj_loss, state.params, data, k1,
                                    fl.batch_size, participation=participation,
                                    participation_key=pk)
        cg, cv, _ = fed.sample_round(cons_loss, state.params, data, k2,
                                     fl.batch_size, with_value=True,
                                     participation=participation,
                                     participation_key=pk)
        new = optimizer.ssca_general_constrained_step(
            state, og, cg, cv, fl, rho_t=inp.rho, gamma_t=inp.gamma)
        return new, {"cons_est": cv, "nu": new.nu, "slack": new.slack}

    state = optimizer.ssca_general_constrained_init(params0)
    return _run(step, state, key, rounds, eval_fn, eval_every,
                lambda s: s.params, fl=fl, driver=driver)


# ---------------------------------------------------------------------------
# Algorithm 3: unconstrained feature-based FL via mini-batch SSCA
# ---------------------------------------------------------------------------


def algorithm3(head_loss_from_h, client_h, params0, data: FeatureFedData, fl,
               rounds: int, key, eval_fn=None, eval_every: int = 10,
               driver: str = "scan") -> RunResult:
    def step(state, inp):
        grad_est, val_est, _ = fed.feature_round(
            state.params, data, inp.key, fl.batch_size, head_loss_from_h,
            client_h)
        new = optimizer.ssca_step(state, grad_est, fl,
                                  rho_t=inp.rho, gamma_t=inp.gamma)
        return new, {"loss_est": val_est}

    state = optimizer.ssca_init(params0)
    return _run(step, state, key, rounds, eval_fn, eval_every,
                lambda s: s.params, fl=fl, driver=driver)


# ---------------------------------------------------------------------------
# Algorithm 4: constrained feature-based FL
# ---------------------------------------------------------------------------


def algorithm4(head_loss_from_h, client_h, params0, data: FeatureFedData, fl,
               rounds: int, key, eval_fn=None, eval_every: int = 10,
               driver: str = "scan") -> RunResult:
    def step(state, inp):
        grad_est, val_est, _ = fed.feature_round(
            state.params, data, inp.key, fl.batch_size, head_loss_from_h,
            client_h)
        new = optimizer.ssca_constrained_step(state, grad_est, val_est, fl,
                                              rho_t=inp.rho, gamma_t=inp.gamma)
        return new, {"loss_est": val_est, "nu": new.nu, "slack": new.slack}

    state = optimizer.ssca_constrained_init(params0)
    return _run(step, state, key, rounds, eval_fn, eval_every,
                lambda s: s.params, fl=fl, driver=driver)

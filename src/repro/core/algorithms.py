"""Drivers for the paper's Algorithms 1-4 (faithful protocol simulation).

Each driver runs T-1 communication rounds with per-round client mini-batch
selection (PRNG-folded), the exact uploads of the paper, and the closed-form
server updates. Rounds are lax.scan-ed in chunks with periodic evaluation.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import fed, optimizer
from repro.core.fed import FeatureFedData, SampleFedData


class RunResult(NamedTuple):
    params: object
    history: dict             # metric name -> (T_evals,) arrays
    final_state: object


def _run(step_fn, state, key, rounds: int, eval_fn: Optional[Callable],
         eval_every: int, extract_params):
    chunk = max(1, eval_every)
    n_chunks = max(1, rounds // chunk)

    @jax.jit
    def run_chunk(state, keys):
        return jax.lax.scan(lambda s, k: (step_fn(s, k), None), state, keys)[0]

    hist = {"round": []}
    for c in range(n_chunks):
        key, sub = jax.random.split(key)
        state = run_chunk(state, jax.random.split(sub, chunk))
        if eval_fn is not None:
            metrics = eval_fn(extract_params(state), state)
            for k, v in metrics.items():
                hist.setdefault(k, []).append(v)
            hist["round"].append((c + 1) * chunk)
    history = {k: jnp.asarray(v) for k, v in hist.items()}
    return RunResult(extract_params(state), history, state)


# ---------------------------------------------------------------------------
# Algorithm 1: unconstrained sample-based FL via mini-batch SSCA
# ---------------------------------------------------------------------------


def algorithm1(per_sample_loss, params0, data: SampleFedData, fl, rounds: int,
               key, eval_fn=None, eval_every: int = 10) -> RunResult:
    def step(state, k):
        grad_est, _, _ = fed.sample_round(per_sample_loss, state.params, data,
                                          k, fl.batch_size)
        return optimizer.ssca_step(state, grad_est, fl)

    state = optimizer.ssca_init(params0)
    return _run(step, state, key, rounds, eval_fn, eval_every, lambda s: s.params)


# ---------------------------------------------------------------------------
# Algorithm 2: constrained sample-based FL (formulation (40): min ‖ω‖², F <= U)
# ---------------------------------------------------------------------------


def algorithm2(per_sample_loss, params0, data: SampleFedData, fl, rounds: int,
               key, eval_fn=None, eval_every: int = 10) -> RunResult:
    def step(state, k):
        grad_est, val_est, _ = fed.sample_round(per_sample_loss, state.params,
                                                data, k, fl.batch_size,
                                                with_value=True)
        return optimizer.ssca_constrained_step(state, grad_est, val_est, fl)

    state = optimizer.ssca_constrained_init(params0)
    return _run(step, state, key, rounds, eval_fn, eval_every, lambda s: s.params)


def algorithm2_general(obj_loss, cons_loss, params0, data: SampleFedData, fl,
                       rounds: int, key, eval_fn=None,
                       eval_every: int = 10) -> RunResult:
    """Full Algorithm 2: sampled nonconvex objective AND constraint."""
    def step(state, k):
        k1, k2 = jax.random.split(k)
        og, _, _ = fed.sample_round(obj_loss, state.params, data, k1, fl.batch_size)
        cg, cv, _ = fed.sample_round(cons_loss, state.params, data, k2,
                                     fl.batch_size, with_value=True)
        return optimizer.ssca_general_constrained_step(state, og, cg, cv, fl)

    state = optimizer.ssca_general_constrained_init(params0)
    return _run(step, state, key, rounds, eval_fn, eval_every, lambda s: s.params)


# ---------------------------------------------------------------------------
# Algorithm 3: unconstrained feature-based FL via mini-batch SSCA
# ---------------------------------------------------------------------------


def algorithm3(head_loss_from_h, client_h, params0, data: FeatureFedData, fl,
               rounds: int, key, eval_fn=None, eval_every: int = 10) -> RunResult:
    def step(state, k):
        grad_est, _, _ = fed.feature_round(state.params, data, k, fl.batch_size,
                                           head_loss_from_h, client_h)
        return optimizer.ssca_step(state, grad_est, fl)

    state = optimizer.ssca_init(params0)
    return _run(step, state, key, rounds, eval_fn, eval_every, lambda s: s.params)


# ---------------------------------------------------------------------------
# Algorithm 4: constrained feature-based FL
# ---------------------------------------------------------------------------


def algorithm4(head_loss_from_h, client_h, params0, data: FeatureFedData, fl,
               rounds: int, key, eval_fn=None, eval_every: int = 10) -> RunResult:
    def step(state, k):
        grad_est, val_est, _ = fed.feature_round(state.params, data, k,
                                                 fl.batch_size,
                                                 head_loss_from_h, client_h)
        return optimizer.ssca_constrained_step(state, grad_est, val_est, fl)

    state = optimizer.ssca_constrained_init(params0)
    return _run(step, state, key, rounds, eval_fn, eval_every, lambda s: s.params)

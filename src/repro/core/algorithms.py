"""Drivers for the paper's Algorithms 1-4 (faithful protocol simulation).

Each driver runs the paper's communication rounds with per-round client
mini-batch selection (PRNG-folded), the exact uploads of the paper, and the
closed-form server updates. The whole round chain is scan-compiled by
``core/rounds.py`` — a K-round run (or eval chunk) is a single XLA dispatch
with ρ^t/γ^t threaded through the scan (DESIGN.md §6).

The sample-based drivers (Algorithms 1/2) take ``participation=S`` to sample
S of I clients uniformly per round, with the unbiased I/S-reweighted
N_i/(B_i·N) aggregation of `fed.aggregation_weights`; they accept ragged
(e.g. Dirichlet-partitioned) client datasets transparently. Adding
``cohort=True`` switches the round body to the participant-only O(S) engine
(`fed.cohort_round`, DESIGN.md §14): per-round compute, uploads, and EF
state scale with S instead of the population I (residuals live in a keyed
`EFStore`, data may be a `data.synthetic.VirtualFedData` so I = 1e6 never
materializes), with the dense path's trajectory reproduced to float
reassociation (atol 1e-5) on the same keys.

Every driver takes ``codec=`` (repro.comm): q-uploads then cross the client
boundary in the codec's wire format, per-client error-feedback residuals
ride through the scan carry in a ``CommCarry`` wrapper, and each round's
metrics gain ``upload_bytes`` — the exact bytes-on-wire of that round's
uplink (repro.comm.accounting), so history["round_upload_bytes"] is the
Fig.-3 x-axis measured, not asserted.

The sample-based drivers also take ``topology=`` (core/topology.py,
DESIGN.md §11): `LocalTopology` (default) vmaps every client on one device;
`ShardedTopology` distributes clients over the mesh's client axes via
shard_map with the q-aggregation as a weighted psum — same trajectories up
to float reassociation, one scan dispatch spanning D devices. Under a
sharded topology the metrics additionally carry ``axis_bytes``, the
per-round bytes the aggregation psum moves over the client mesh axis
(repro.comm.accounting.psum_axis_bytes).

Every driver also takes ``dp=`` (repro.core.privacy.DPConfig, DESIGN.md
§15): client q-uploads are then clipped and Gaussian-noised at the client
boundary BEFORE any codec encode, and each round's metrics gain
``dp_epsilon`` (the subsampled-RDP accountant's ε spent through round t —
cross-round composition, in-graph via RoundInputs.t), ``dp_clip_frac``
(fraction of participating clients whose upload hit the clip norm), and
``dp_noise_norm`` (ℓ2 norm of the injected noise). Partial participation
(``participation=S`` / the cohort engine) is accounted with the q = S/I
subsampling amplification.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.comm import accounting as comm_accounting
from repro.comm import codecs as comm_codecs
from repro.comm.error_feedback import (CommCarry, ef_init, ef_init_stacked,
                                       ef_store_init, with_comm_carry)
from repro.core import fed, optimizer
from repro.core import privacy as privacy_lib
from repro.core import rounds as rounds_lib
from repro.core.fed import FeatureFedData, SampleFedData
from repro.core.rounds import RunResult  # re-exported (public API since seed)


def _run(step_fn, state, key, num_rounds: int, eval_fn: Optional[Callable],
         eval_every: int, extract_params=None, fl=None, driver: str = "scan",
         topology=None, obs=None):
    """Back-compat driver shim shared with baselines/local_updates: step_fn
    has the rounds.py signature step(state, RoundInputs-slice) -> (state,
    metrics). fl is only needed for the schedule inputs; steps that ignore
    rho/gamma (SGD baselines) may pass fl=None. extract_params=None uses the
    CommCarry-aware default (rounds.unwrap_comm). topology is forwarded so
    run_rounds can pre-place per-client carry state on the mesh; obs
    (repro.obs.MetricStream) streams each round's metrics while the scan
    runs."""
    fl = fl if fl is not None else _NULL_SCHED
    return rounds_lib.run_rounds(step_fn, state, fl, key, num_rounds,
                             eval_fn=eval_fn, eval_every=eval_every,
                             extract_params=extract_params, driver=driver,
                             topology=topology, obs=obs)


def _axis_bytes_metric(topology, grad_est, with_value: bool = False,
                       num_streams: int = 1):
    """Static per-round bytes over the client mesh axis (0.0 for local):
    the psum realization of the eq.-(9) aggregation moves pre-weighted
    partial sums, accounted once per driver here. grad_est only supplies
    the (trace-time static) flat dimension."""
    shards = getattr(topology, "num_shards", 1) if topology is not None else 1
    return float(comm_accounting.psum_axis_bytes(
        comm_codecs.tree_flat_dim(grad_est), shards, with_value=with_value,
        num_streams=num_streams))


def _sample_upload_bytes(uploads, grad_est, data, participation,
                         with_value: bool = False):
    """Static per-round uplink bytes metric: with a codec, fed.sample_round
    already computed the exact wire bytes (uploads["upload_nbytes"]) — reuse
    it so accounting has ONE call site per round; the dense path derives the
    fp32 bytes from the (trace-time static) grad shapes."""
    if uploads["upload_nbytes"] is not None:
        return float(uploads["upload_nbytes"])
    return float(comm_accounting.sample_round_bytes(
        comm_codecs.tree_flat_dim(grad_est), data.num_clients, None,
        participation=participation, with_value=with_value)["up"])


def _wrap_codec_state(state, codec, ef0):
    """The single CommCarry construction site for every driver: attach the
    zeroed EF residuals (built by the ef0 thunk, so the dense path allocates
    nothing) when a codec is in play."""
    if codec is None:
        return state
    return CommCarry(opt=state, ef=ef0())


def _sample_ef0(params0, num_clients: int, cohort: bool = False):
    """Zeroed per-client EF residuals for sample-based q-uploads: a dense
    (I, P) matrix for the reference engine, a keyed `EFStore` (same backing,
    gathered O(S) rows per round) for the cohort engine."""
    dim = comm_codecs.tree_flat_dim(params0)
    if cohort:
        return ef_store_init(num_clients, dim)
    return ef_init_stacked(num_clients, dim)


def _check_cohort(name: str, cohort: bool, participation):
    """The cohort engine IS a partial-participation engine — S is its
    per-round shape; reject cohort=True without participation=S early."""
    if cohort and participation is None:
        raise ValueError(
            f"{name}: cohort=True needs participation=S (the O(S) engine's "
            "per-round cohort size); pass participation= or drop cohort=")


def _cohort_ef_norm(up):
    """ef_norm for the cohort engine: the norm of the cohort's own updated
    residual rows (O(S·P)) — NOT the full (I, P) backing, which would put an
    O(I) reduction back into every round. Stream semantics therefore differ
    from the dense engine's all-clients norm; don't compare across engines."""
    return _ef_norm(jax.tree.map(
        lambda store: store.gather(up["cohort"]), up["ef"],
        is_leaf=lambda v: hasattr(v, "gather")))


def _stat_res(new_params, old_params, gamma_t):
    """Per-round stationarity residual ‖ω^{t+1} − ω^t‖₂ / γ^t = ‖ω̄^t − ω^t‖₂
    (the update is ω ← (1−γ)ω + γω̄, eq. 5) — the quantity Theorems 1/2
    drive to 0, now a streamed metric on every SSCA driver."""
    d = jax.tree.map(
        lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
        new_params, old_params)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x))
                        for x in jax.tree.leaves(d))) / jnp.maximum(
                            gamma_t, 1e-30)


def _ef_norm(ef):
    """‖EF residuals‖₂ across every stream — the amount of signal the codec
    is still holding back (decays iff error feedback is keeping up)."""
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(ef)))


def _dp_sample_rate(participation, num_clients: int) -> float:
    """Accountant subsampling rate q for a sample-based driver: S/I under
    partial participation (dense mask or cohort engine — both draw S of I
    uniformly without replacement, accounted with the standard Poisson-
    subsampling RDP bound, conservative here), 1.0 at full participation."""
    if participation is None or participation >= num_clients:
        return 1.0
    return participation / num_clients


def _dp_metrics(eps_fn, stats, mask, inp):
    """Per-round DP metrics from the uploads["dp"] stats of a sample-based
    round. `mask` is the dense participation mask (None on the cohort path
    and at full participation: every row of `stats` then belongs to a real
    participant). dp_epsilon is ε spent through round t — the accountant's
    cross-round composition evaluated in-graph at inp.t."""
    clipped, noise_sq = stats["clipped"], stats["noise_sq"]
    if mask is None:
        mask = jnp.ones_like(clipped)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return {"dp_epsilon": eps_fn(inp.t),
            "dp_clip_frac": jnp.sum(clipped * mask) / denom,
            "dp_noise_norm": jnp.sqrt(jnp.sum(noise_sq * mask))}


def _dp_feature_metrics(eps_fn, stats, num_clients: int, inp):
    """Feature-round variant: one head stream + I block streams, all
    released every round (the clip fraction averages over the I+1 uploads)."""
    return {"dp_epsilon": eps_fn(inp.t),
            "dp_clip_frac": (stats["head_clipped"]
                             + jnp.sum(stats["blocks_clipped"]))
            / (num_clients + 1.0),
            "dp_noise_norm": jnp.sqrt(stats["head_noise_sq"]
                                      + jnp.sum(stats["blocks_noise_sq"]))}


class _NullSched:
    a1 = a2 = 1.0
    alpha_rho = alpha_gamma = 1.0


_NULL_SCHED = _NullSched()


# ---------------------------------------------------------------------------
# Algorithm 1: unconstrained sample-based FL via mini-batch SSCA
# ---------------------------------------------------------------------------


def make_algorithm1_step(per_sample_loss, data: SampleFedData, fl,
                         participation: Optional[int] = None, codec=None,
                         topology=None, cohort: bool = False, dp=None):
    """One full Algorithm-1 round as a pure (state, RoundInputs) step —
    batch selection, uploads (optionally codec-compressed with error
    feedback), aggregation, surrogate recursion, update — suitable for
    lax.scan (rounds.scan_rounds) or per-round dispatch. With a codec the
    state is a CommCarry(opt=SSCAState, ef=(I, P) residuals). topology
    selects the client-execution engine (DESIGN.md §11). cohort=True runs
    the participant-only O(S) engine (fed.cohort_round, DESIGN.md §14):
    ef becomes a keyed EFStore and topology shards the cohort axis. dp=
    privatizes every q-upload (DESIGN.md §15) and adds the dp_* metrics."""
    _check_cohort("make_algorithm1_step", cohort, participation)
    eps_fn = (privacy_lib.make_eps_fn(
        dp, _dp_sample_rate(participation, data.num_clients))
        if dp is not None else None)

    def body(state, inp, ef):
        if cohort:
            grad_est, val_est, up = fed.cohort_round(
                per_sample_loss, state.params, data, inp.key, fl.batch_size,
                participation, codec=codec, ef=ef, topology=topology, dp=dp)
        else:
            grad_est, val_est, up = fed.sample_round(
                per_sample_loss, state.params, data, inp.key, fl.batch_size,
                participation=participation, codec=codec, ef=ef,
                topology=topology, dp=dp)
        new = optimizer.ssca_step(state, grad_est, fl,
                                  rho_t=inp.rho, gamma_t=inp.gamma)
        metrics = {"loss_est": val_est,
                   "stat_res": _stat_res(new.params, state.params, inp.gamma),
                   "upload_bytes": _sample_upload_bytes(
                       up, grad_est, data, participation),
                   "axis_bytes": _axis_bytes_metric(topology, grad_est)}
        if codec is not None:
            metrics["ef_norm"] = (_cohort_ef_norm(up) if cohort
                                  else _ef_norm(up["ef"]))
        if dp is not None:
            metrics.update(_dp_metrics(eps_fn, up["dp"],
                                       up.get("participants"), inp))
        return new, up["ef"], metrics

    return with_comm_carry(codec, body)


def algorithm1(per_sample_loss, params0, data: SampleFedData, fl, rounds: int,
               key, eval_fn=None, eval_every: int = 10,
               participation: Optional[int] = None,
               driver: str = "scan", codec=None, topology=None,
               obs=None, cohort: bool = False, dp=None) -> RunResult:
    step = make_algorithm1_step(per_sample_loss, data, fl, participation,
                                codec, topology, cohort, dp)
    state = _wrap_codec_state(
        optimizer.ssca_init(params0), codec,
        lambda: _sample_ef0(params0, data.num_clients, cohort))
    return _run(step, state, key, rounds, eval_fn, eval_every,
                fl=fl, driver=driver, topology=topology, obs=obs)


# ---------------------------------------------------------------------------
# Algorithm 2: constrained sample-based FL (formulation (40): min ‖ω‖², F <= U)
# ---------------------------------------------------------------------------


def make_algorithm2_step(per_sample_loss, data: SampleFedData, fl,
                         participation: Optional[int] = None, codec=None,
                         topology=None, cohort: bool = False, dp=None):
    _check_cohort("make_algorithm2_step", cohort, participation)
    # NOTE: dp= privatizes the q-grad uploads; the scalar q-value (loss) sums
    # that with_value=True also releases are NOT noised — the accountant
    # covers the gradient stream only (documented limitation, DESIGN.md §15).
    eps_fn = (privacy_lib.make_eps_fn(
        dp, _dp_sample_rate(participation, data.num_clients))
        if dp is not None else None)

    def body(state, inp, ef):
        if cohort:
            grad_est, val_est, up = fed.cohort_round(
                per_sample_loss, state.params, data, inp.key, fl.batch_size,
                participation, with_value=True, codec=codec, ef=ef,
                topology=topology, dp=dp)
        else:
            grad_est, val_est, up = fed.sample_round(
                per_sample_loss, state.params, data, inp.key, fl.batch_size,
                with_value=True, participation=participation, codec=codec,
                ef=ef, topology=topology, dp=dp)
        new = optimizer.ssca_constrained_step(state, grad_est, val_est, fl,
                                              rho_t=inp.rho, gamma_t=inp.gamma)
        metrics = {"loss_est": val_est, "nu": new.nu, "slack": new.slack,
                   "stat_res": _stat_res(new.params, state.params, inp.gamma),
                   "cons_viol": jnp.maximum(val_est - fl.cost_limit, 0.0),
                   "upload_bytes": _sample_upload_bytes(
                       up, grad_est, data, participation, with_value=True),
                   "axis_bytes": _axis_bytes_metric(topology, grad_est,
                                                    with_value=True)}
        if codec is not None:
            metrics["ef_norm"] = (_cohort_ef_norm(up) if cohort
                                  else _ef_norm(up["ef"]))
        if dp is not None:
            metrics.update(_dp_metrics(eps_fn, up["dp"],
                                       up.get("participants"), inp))
        return new, up["ef"], metrics

    return with_comm_carry(codec, body)


def algorithm2(per_sample_loss, params0, data: SampleFedData, fl, rounds: int,
               key, eval_fn=None, eval_every: int = 10,
               participation: Optional[int] = None,
               driver: str = "scan", codec=None, topology=None,
               obs=None, cohort: bool = False, dp=None) -> RunResult:
    step = make_algorithm2_step(per_sample_loss, data, fl, participation,
                                codec, topology, cohort, dp)
    state = _wrap_codec_state(
        optimizer.ssca_constrained_init(params0), codec,
        lambda: _sample_ef0(params0, data.num_clients, cohort))
    return _run(step, state, key, rounds, eval_fn, eval_every,
                fl=fl, driver=driver, topology=topology, obs=obs)


def algorithm2_general(obj_loss, cons_loss, params0, data: SampleFedData, fl,
                       rounds: int, key, eval_fn=None, eval_every: int = 10,
                       participation: Optional[int] = None,
                       driver: str = "scan", codec=None,
                       topology=None, obs=None,
                       cohort: bool = False, dp=None) -> RunResult:
    """Full Algorithm 2: sampled nonconvex objective AND constraint. With a
    codec the objective and constraint q-uploads carry separate EF
    residuals (ef = {"obj": (I, P), "cons": (I, P)}); under a sharded
    topology both aggregations psum over the client axes (two streams).
    cohort=True runs both streams through the O(S) engine — the shared
    participation key makes each stream re-derive the SAME cohort ids, and
    each stream's residuals live in their own keyed EFStore. dp= privatizes
    BOTH q-grad streams (independent noise keys per stream), so the
    accountant composes 2 releases per round."""
    _check_cohort("algorithm2_general", cohort, participation)
    eps_fn = (privacy_lib.make_eps_fn(
        dp, _dp_sample_rate(participation, data.num_clients),
        releases_per_round=2) if dp is not None else None)

    def body(state, inp, ef):
        ef = ef if ef is not None else {"obj": None, "cons": None}
        k1, k2 = jax.random.split(inp.key)
        # ONE participant set per round: both the objective and the constraint
        # statistics are uploaded by the same S clients (faithful protocol).
        pk = jax.random.fold_in(inp.key, 0x5ca)
        if cohort:
            og, _, uo = fed.cohort_round(obj_loss, state.params, data, k1,
                                         fl.batch_size, participation,
                                         participation_key=pk, codec=codec,
                                         ef=ef["obj"], topology=topology,
                                         dp=dp)
            cg, cv, uc = fed.cohort_round(cons_loss, state.params, data, k2,
                                          fl.batch_size, participation,
                                          with_value=True,
                                          participation_key=pk, codec=codec,
                                          ef=ef["cons"], topology=topology,
                                          dp=dp)
        else:
            og, _, uo = fed.sample_round(obj_loss, state.params, data, k1,
                                         fl.batch_size,
                                         participation=participation,
                                         participation_key=pk, codec=codec,
                                         ef=ef["obj"], topology=topology,
                                         dp=dp)
            cg, cv, uc = fed.sample_round(cons_loss, state.params, data, k2,
                                          fl.batch_size, with_value=True,
                                          participation=participation,
                                          participation_key=pk, codec=codec,
                                          ef=ef["cons"], topology=topology,
                                          dp=dp)
        new = optimizer.ssca_general_constrained_step(
            state, og, cg, cv, fl, rho_t=inp.rho, gamma_t=inp.gamma)
        bts = (_sample_upload_bytes(uo, og, data, participation)
               + _sample_upload_bytes(uc, cg, data, participation,
                                      with_value=True))
        metrics = {"cons_est": cv, "nu": new.nu, "slack": new.slack,
                   "stat_res": _stat_res(new.params, state.params, inp.gamma),
                   "cons_viol": jnp.maximum(cv - fl.cost_limit, 0.0),
                   "upload_bytes": bts,
                   "axis_bytes": (_axis_bytes_metric(topology, og)
                                  + _axis_bytes_metric(topology, cg,
                                                       with_value=True))}
        new_ef = {"obj": uo["ef"], "cons": uc["ef"]}
        if codec is not None:
            metrics["ef_norm"] = (
                _cohort_ef_norm({"cohort": uo["cohort"], "ef": new_ef})
                if cohort else _ef_norm(new_ef))
        if dp is not None:
            pm = uo.get("participants")
            mo = _dp_metrics(eps_fn, uo["dp"], pm, inp)
            mc = _dp_metrics(eps_fn, uc["dp"], pm, inp)
            metrics.update({
                "dp_epsilon": mo["dp_epsilon"],
                "dp_clip_frac": 0.5 * (mo["dp_clip_frac"]
                                       + mc["dp_clip_frac"]),
                "dp_noise_norm": jnp.sqrt(jnp.square(mo["dp_noise_norm"])
                                          + jnp.square(mc["dp_noise_norm"]))})
        return new, new_ef, metrics

    step = with_comm_carry(codec, body)
    state = _wrap_codec_state(
        optimizer.ssca_general_constrained_init(params0), codec,
        lambda: {"obj": _sample_ef0(params0, data.num_clients, cohort),
                 "cons": _sample_ef0(params0, data.num_clients, cohort)})
    return _run(step, state, key, rounds, eval_fn, eval_every,
                fl=fl, driver=driver, topology=topology, obs=obs)


# ---------------------------------------------------------------------------
# Algorithm 3: unconstrained feature-based FL via mini-batch SSCA
# ---------------------------------------------------------------------------


def _run_feature(step_fn, state, key, num_rounds: int,
                 eval_fn: Optional[Callable], eval_every: int,
                 extract_params=None, fl=None, driver: str = "scan",
                 topology=None, obs=None):
    """Feature-based `_run`: same shim, but the per-client carry placement is
    the feature-EF dict layout (rounds.run_feature_rounds /
    topology.place_feature_state). Shared with baselines' feature drivers."""
    fl = fl if fl is not None else _NULL_SCHED
    return rounds_lib.run_feature_rounds(
        step_fn, state, fl, key, num_rounds, eval_fn=eval_fn,
        eval_every=eval_every, extract_params=extract_params, driver=driver,
        topology=topology, obs=obs)


def _feature_axis_bytes(topology, uploads):
    """Static per-round bytes over the client mesh axis for a feature round
    (0.0 for local): the all_gather realization of the step-4 h-broadcast
    moves the full (I, B, J) h; uploads only supplies the (trace-time
    static) element count."""
    shards = getattr(topology, "num_shards", 1) if topology is not None else 1
    return float(comm_accounting.all_gather_axis_bytes(
        uploads["h_exchange"].size, shards))


def _feature_upload_bytes(uploads, grad_est, data, batch_size: int):
    """Per-round uplink bytes of a feature-based round: the codec path reuses
    fed.feature_round's exact figure, the dense path derives fp32 bytes from
    the (static) upload shapes. Shared with baselines.feature_sgd."""
    if uploads["upload_nbytes"] is not None:
        return float(uploads["upload_nbytes"])
    return float(comm_accounting.feature_round_bytes(
        comm_codecs.tree_flat_dim(grad_est["w0"]),
        [comm_codecs.tree_flat_dim(grad_est["blocks"], stacked=True)]
        * data.num_clients,
        batch_size, uploads["h_exchange"].shape[-1],
        data.num_clients)["up"])


def _feature_ef0(params0, num_clients: int):
    """Zeroed EF residuals for the feature-based uploads: one head stream +
    one per-client block stream."""
    return {"w0": ef_init(comm_codecs.tree_flat_dim(params0["w0"])),
            "blocks": ef_init_stacked(
                num_clients,
                comm_codecs.tree_flat_dim(params0["blocks"], stacked=True))}


def _make_feature_step(head_loss_from_h, client_h, data, fl, codec,
                       update_fn, topology=None, dp=None):
    """Shared Algorithm-3/4 step body: feature_round + the given optimizer
    update, with optional codec/EF threading. topology selects the feature
    client-execution engine (DESIGN.md §12). dp= privatizes the head and
    block q-uploads — all I clients release every round (q = 1) and the
    head + block streams count as 2 releases per round for the accountant;
    the step-4 h-exchange stays unprivatized (fed.feature_round docstring)."""
    eps_fn = (privacy_lib.make_eps_fn(dp, 1.0, releases_per_round=2)
              if dp is not None else None)

    def body(state, inp, ef):
        grad_est, val_est, up = fed.feature_round(
            state.params, data, inp.key, fl.batch_size, head_loss_from_h,
            client_h, codec=codec, ef=ef, topology=topology, dp=dp)
        new, metrics = update_fn(state, grad_est, val_est, inp)
        metrics["stat_res"] = _stat_res(new.params, state.params, inp.gamma)
        metrics["upload_bytes"] = _feature_upload_bytes(up, grad_est, data,
                                                       fl.batch_size)
        metrics["axis_bytes"] = _feature_axis_bytes(topology, up)
        if codec is not None:
            metrics["ef_norm"] = _ef_norm(up["ef"])
        if dp is not None:
            metrics.update(_dp_feature_metrics(eps_fn, up["dp"],
                                               data.num_clients, inp))
        return new, up["ef"], metrics

    return with_comm_carry(codec, body)


def algorithm3(head_loss_from_h, client_h, params0, data: FeatureFedData, fl,
               rounds: int, key, eval_fn=None, eval_every: int = 10,
               driver: str = "scan", codec=None, topology=None,
               obs=None, dp=None) -> RunResult:
    def update(state, grad_est, val_est, inp):
        new = optimizer.ssca_step(state, grad_est, fl,
                                  rho_t=inp.rho, gamma_t=inp.gamma)
        return new, {"loss_est": val_est}

    step = _make_feature_step(head_loss_from_h, client_h, data, fl, codec,
                              update, topology, dp)
    state = _wrap_codec_state(optimizer.ssca_init(params0), codec,
                              lambda: _feature_ef0(params0, data.num_clients))
    return _run_feature(step, state, key, rounds, eval_fn, eval_every,
                        fl=fl, driver=driver, topology=topology, obs=obs)


# ---------------------------------------------------------------------------
# Algorithm 4: constrained feature-based FL
# ---------------------------------------------------------------------------


def algorithm4(head_loss_from_h, client_h, params0, data: FeatureFedData, fl,
               rounds: int, key, eval_fn=None, eval_every: int = 10,
               driver: str = "scan", codec=None, topology=None,
               obs=None, dp=None) -> RunResult:
    def update(state, grad_est, val_est, inp):
        new = optimizer.ssca_constrained_step(state, grad_est, val_est, fl,
                                              rho_t=inp.rho, gamma_t=inp.gamma)
        return new, {"loss_est": val_est, "nu": new.nu, "slack": new.slack,
                     "cons_viol": jnp.maximum(val_est - fl.cost_limit, 0.0)}

    step = _make_feature_step(head_loss_from_h, client_h, data, fl, codec,
                              update, topology, dp)
    state = _wrap_codec_state(optimizer.ssca_constrained_init(params0), codec,
                              lambda: _feature_ef0(params0, data.num_clients))
    return _run_feature(step, state, key, rounds, eval_fn, eval_every,
                        fl=fl, driver=driver, topology=topology, obs=obs)

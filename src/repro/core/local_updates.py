"""Beyond-paper extension: multiple LOCAL SSCA updates per communication round.

The paper's conclusion names this as the main open direction: "design advanced
SSCA-based FL algorithms that allow multiple local updates to reduce
communication costs further." We implement it by exploiting Remark 2: the
Algorithm-1 example IS momentum SGD, so a client can run E local
momentum-form SSCA steps (its own minibatches, its own transient surrogate
buffer) and upload only the resulting model delta; the server averages deltas
with the N_i/N weights and applies the global relaxation. E=1 recovers
Algorithm 1 exactly (tested).

Per-round communication is unchanged (d floats each way); computation per
round grows E×; rounds-to-target shrinks — the same tradeoff the paper plots
for FedAvg/PR-SGD in Fig. 3, now available to SSCA.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import fed
from repro.core import topology as topology_lib
from repro.core.algorithms import RunResult, _check_cohort, _run
from repro.core.fed import SampleFedData
from repro.core.tree import tree_zeros_like


class LocalSSCAState(NamedTuple):
    params: object
    v: object                 # server-level momentum (the surrogate buffer)
    t: jnp.ndarray


def algorithm1_local(per_sample_loss, params0, data: SampleFedData, fl,
                     rounds: int, key, *, local_steps: int = 4,
                     eval_fn=None, eval_every: int = 10,
                     topology=None, obs=None, participation=None,
                     cohort: bool = False) -> RunResult:
    """Algorithm 1 with E local SSCA (momentum-form) refinements per round.
    ``topology=`` runs the E-step client loops on the mesh (the upload here
    is the {model, momentum} pair, both N_i/N weighted-summed).

    ``participation=S`` averages over an S-client cohort with COHORT-
    normalized weights N_i/Σ_{j∈cohort} N_j — the uploads are full models,
    so the weights must stay a convex combination (Horvitz-Thompson
    inflation would overshoot the iterate); this is standard FedAvg-style
    cohort averaging, unbiased only conditionally on the draw. ``cohort=
    True`` runs it as the participant-only O(S) engine (DESIGN.md §14),
    reproducing the dense masked trajectory to float reassociation."""
    topo = topology if topology is not None else topology_lib.LOCAL
    _check_cohort("algorithm1_local", cohort, participation)
    num_clients = data.num_clients

    def local(params, v, feat_i, lab_i, count_i, k, rho_t, gamma_t):
        def one(step, carry):
            p, vv = carry
            kk = jax.random.fold_in(k, step)
            idx = jax.random.randint(kk, (fl.batch_size,), 0, count_i)
            zb = jnp.take(feat_i, idx, 0)
            yb = jnp.take(lab_i, idx, 0)
            g = jax.grad(lambda q: jnp.mean(per_sample_loss(q, zb, yb)))(p)
            g = jax.tree.map(lambda gg, pp: gg + 2 * fl.l2_lambda * pp, g, p)
            # local momentum-form SSCA step (eqs. 11-12 with frozen rho/gamma)
            vv = jax.tree.map(
                lambda a, b: (1 - rho_t) * (1 - gamma_t) * a
                + rho_t / (2 * fl.tau) * b, vv, g)
            p = jax.tree.map(lambda pp, a: pp - gamma_t * a, p, vv)
            return p, vv

        return jax.lax.fori_loop(0, local_steps, one, (params, v))

    def step(state, inp):
        rho_t, gamma_t = inp.rho, inp.gamma

        def client_fn(f_, l_, c_, k_):
            p_i, v_i = local(state.params, state.v, f_, l_, c_, k_,
                             rho_t, gamma_t)
            return {"params": p_i, "v": v_i}, jnp.zeros((), jnp.float32)

        # server: weighted model/momentum averaging (uploads: d floats each);
        # the weights are cohort-normalized to a convex combination in every
        # participation mode (see docstring)
        if cohort:
            pk = jax.random.fold_in(inp.key, 0x5ca)
            ids = fed.cohort_sample(pk, num_clients, participation)
            feats, labs, counts_s = data.shards_for(ids)
            keys = fed.client_keys(inp.key, ids)
            cf = counts_s.astype(jnp.float32)
            s = topo.weighted_sum(client_fn, (feats, labs, counts_s, keys),
                                  cf / jnp.sum(cf))
        else:
            keys = fed.client_keys(inp.key, jnp.arange(num_clients))
            cf = data.counts.astype(jnp.float32)
            if participation is not None and participation < num_clients:
                pmask = fed.participation_mask(
                    jax.random.fold_in(inp.key, 0x5ca), num_clients,
                    participation)
                cf = cf * pmask
            s = topo.weighted_sum(
                client_fn, (data.features, data.labels, data.counts, keys),
                cf / jnp.sum(cf))
        return LocalSSCAState(params=s.weighted["params"], v=s.weighted["v"],
                              t=state.t + 1), {}

    state = LocalSSCAState(params=params0, v=tree_zeros_like(params0),
                           t=jnp.ones((), jnp.int32))
    return _run(step, state, key, rounds, eval_fn, eval_every,
                lambda s: s.params, fl=fl, topology=topology, obs=obs)

"""Scan-compiled multi-round federated driver (see DESIGN.md §6).

The seed runtime drove communication rounds from a Python loop: one XLA
dispatch per round, schedule powers recomputed from the carried t, metrics
only observable at chunk boundaries. This module folds the *entire* SSCA
round chain — client mini-batch selection (paper step 4), q-statistic uploads,
N_i/(B_i·N) aggregation, surrogate recursion (eq. 9), and the closed-form
update (eq. 10) / constrained Lemma-1 step — into a single ``lax.scan`` over
rounds, so a K-round epoch is ONE dispatch:

    inputs = make_inputs(fl, t0, K, key)         # per-round (key, ρ^t, γ^t)
    state, hist = scan_rounds(step_fn, state, inputs)

Per-round ρ^t/γ^t are precomputed on the host (including the paper's ρ^(1)=1
convention) and threaded through the scan as stacked inputs alongside the
per-round PRNG keys; the round counter t rides in the optimizer state as the
scan carry. Every step emits a metrics dict of scalars, which the scan stacks
into (K,)-arrays — full per-round trajectories for free, where the Python
loop only saw chunk boundaries.

``loop_rounds`` is the semantics-identical per-round-dispatch reference used
by the equivalence test (tests/test_rounds.py) and the scan-vs-loop
rounds-per-second benchmark (benchmarks/rounds_bench.py).

The scan composes with the topology layer (core/topology.py, DESIGN.md §11):
a step whose round body runs clients under a ``ShardedTopology`` embeds a
shard_map inside the scanned step, so K rounds across D devices are still
ONE dispatch, with the per-round q-aggregation as a weighted psum. The only
per-client state in the carry is the error-feedback residual matrix (I, P);
``run_rounds(..., topology=)`` pre-places it over the client axes
(`topology.place_state`) so the carry starts sharded instead of being
resharded by the first shard_map entry.
"""
from __future__ import annotations

import weakref
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import schedules
from repro.obs import trace as obs_trace


class RoundInputs(NamedTuple):
    """Per-round scan inputs: each leaf has a leading (K,) round axis."""
    key: jnp.ndarray          # (K, 2) per-round PRNG keys
    rho: jnp.ndarray          # (K,) ρ^t
    gamma: jnp.ndarray        # (K,) γ^t
    t: jnp.ndarray            # (K,) global 1-based round numbers (int32) —
                              # labels the obs tap's streamed rows and drives
                              # the DP accountant's in-graph ε-so-far
                              # (privacy.make_eps_fn: RDP composition is
                              # linear in t); steps may ignore it

    @property
    def num_rounds(self):
        return self.rho.shape[0]


def schedule_arrays(fl, t_start: int, num_rounds: int):
    """(ρ^t, γ^t) for t = t_start .. t_start+K-1, with the paper's ρ^(1) = 1
    convention applied (§III-A, before eq. (11)) — matches optimizer._sched."""
    t = jnp.arange(t_start, t_start + num_rounds)
    rho = jnp.where(t == 1, 1.0, schedules.rho(t, fl.a1, fl.alpha_rho))
    gamma = schedules.gamma(t, fl.a2, fl.alpha_gamma)
    return rho, gamma


def make_inputs(fl, t_start: int, num_rounds: int, key) -> RoundInputs:
    rho, gamma = schedule_arrays(fl, t_start, num_rounds)
    return RoundInputs(key=jax.random.split(key, num_rounds),
                       rho=rho, gamma=gamma,
                       t=jnp.arange(t_start, t_start + num_rounds,
                                    dtype=jnp.int32))


def scan_rounds(step_fn: Callable, state, inputs: RoundInputs):
    """Run K = inputs.num_rounds rounds as ONE jitted lax.scan dispatch.

    step_fn(state, inp) -> (state, metrics-dict-of-scalars); returns the final
    state and the metrics dict stacked to (K,) arrays. The jitted callable is
    cached per step_fn identity (bounded LRU), so chunked callers and repeat
    invocations with the same step compile once.
    """
    return _scan_jit(step_fn)(state, inputs)


# Caches keyed weakly by step_fn identity. Cross-CALL reuse (not just within
# one run_rounds) is load-bearing: chunked runs and the benchmark's timing
# repeats re-invoke scan_rounds/loop_rounds with the same step and must not
# retrace. Weak keying ties each entry's lifetime to the caller's step
# closure — a step captures its whole client dataset, and the compiled
# executable bakes those arrays in as constants, so the entry (and the
# dataset) is released as soon as the caller drops the closure. The cached
# callable itself only holds a weakref to step_fn, which is live whenever
# the entry is reachable.
_SCAN_CACHE = weakref.WeakKeyDictionary()
_STEP_CACHE = weakref.WeakKeyDictionary()


def _weak_cached(cache, step_fn, make):
    fn = cache.get(step_fn)
    if fn is None:
        fn = make(weakref.ref(step_fn))
        cache[step_fn] = fn
    return fn


def _scan_jit(step_fn):
    # the step runs under the "round" named scope so profiler dumps
    # attribute device time to the protocol phase (obs/trace.py)
    return _weak_cached(
        _SCAN_CACHE, step_fn,
        lambda ref: jax.jit(
            lambda state, inputs: jax.lax.scan(
                obs_trace.scoped("round", ref()), state, inputs)))


def _step_jit(step_fn):
    return _weak_cached(
        _STEP_CACHE, step_fn,
        lambda ref: jax.jit(
            lambda state, inp: obs_trace.scoped("round", ref())(state, inp)))


def loop_rounds(step_fn: Callable, state, inputs: RoundInputs):
    """Reference driver: same step, one jitted dispatch per round (the seed's
    execution model). Kept for the equivalence test and the benchmark. The
    jitted step shares the bounded per-step cache, so repeat calls (benchmark
    timing loops, chunked runs) do not retrace."""
    step = _step_jit(step_fn)
    ms = []
    for r in range(inputs.num_rounds):
        state, m = step(state, jax.tree.map(lambda x: x[r], inputs))
        ms.append(m)
    stacked = {k: jnp.stack([m[k] for m in ms]) for k in ms[0]} if ms else {}
    return state, stacked


class RunResult(NamedTuple):
    params: object
    history: dict             # eval-metric name -> (n_evals,) + per-round arrays
    final_state: object       # full scan carry (incl. any CommCarry EF state)


def unwrap_comm(state):
    """Peel communication-compression carries off a scan state.

    With a codec, drivers wrap their optimizer state in
    ``repro.comm.error_feedback.CommCarry(opt=..., ef=...)`` so the
    error-feedback residuals round-trip through the ``lax.scan`` carry as
    regular pytree state. This walks ``.opt`` links until it reaches the
    state that owns ``.params`` (no-op for unwrapped states)."""
    while not hasattr(state, "params") and hasattr(state, "opt"):
        state = state.opt
    return state


def _default_extract(state):
    return unwrap_comm(state).params


ENGINES = {"scan": scan_rounds, "loop": loop_rounds}


def chunk_sizes(rounds: int, chunk: int):
    """Split `rounds` into chunk-sized dispatches, never dropping the partial
    final chunk (shared invariant of run_rounds and launch/train.py)."""
    chunk = max(1, min(chunk, rounds))
    sizes = [chunk] * (rounds // chunk)
    if rounds % chunk:
        sizes.append(rounds % chunk)
    return sizes


def _check_eval_keys(metrics, step_metric_names):
    """Eval-hook metrics share the history dict with the per-round scan-step
    series — a same-named key would silently overwrite the (K,) series (or
    corrupt the "round" index). Collisions are an error, not a merge."""
    reserved = {"round", "round_t"}
    reserved.update("round_" + k for k in step_metric_names)
    bad = sorted(set(metrics) & reserved)
    if bad:
        raise ValueError(
            f"eval_fn metric keys {bad} collide with the per-round history "
            "series (\"round\", \"round_t\", and \"round_<step metric>\" "
            "are reserved) — rename them, e.g. namespace as 'eval/<name>'")


def _emit_eval(obs, metrics, t_global: int):
    """Stream an eval-hook result through the obs tap (scalar-coercible
    values only — eval hooks may return arrays, which stay history-only)."""
    row = {"kind": "eval", "t": int(t_global)}
    for k, v in metrics.items():
        try:
            row[k] = float(v)
        except (TypeError, ValueError):
            continue
    # no sync needed: events ride the drainer queue behind the chunk's
    # flush, so the finished chunk's round rows land first anyway
    obs.emit_event(row)


def run_rounds(step_fn: Callable, state, fl, key, rounds: int,
               eval_fn: Optional[Callable] = None, eval_every: int = 0,
               extract_params: Optional[Callable] = None,
               t_start: int = 1, driver: str = "scan",
               topology=None, obs=None) -> RunResult:
    """High-level driver: scan-compile rounds, with optional periodic host
    evaluation between scan chunks.

    With eval_fn=None the K rounds are one dispatch; with eval_every=E each
    E-round chunk is one dispatch and eval_fn(params, state) runs between
    chunks. history carries the eval series under their own names keyed by
    "round", plus every step metric as a full (K,) per-round series under
    "round_<name>" (with "round_t" = t_start..t_start+K-1). Eval metric
    names that would shadow a per-round series raise (no silent overwrite).

    ``topology`` (core/topology.py) is the client-execution engine the step
    was built with; passing it here lets the driver pre-place per-client
    carry state (EF residuals) over the mesh before the first dispatch.

    ``obs`` (repro.obs.MetricStream) streams every round's metrics to host
    sinks *while* each dispatch runs, and interleaves eval results into the
    same log; trajectories and the returned history are bitwise-unchanged
    (DESIGN.md §13).
    """
    engine = ENGINES[driver]
    if topology is not None:
        state = topology.place_state(state)
    extract_params = extract_params or _default_extract
    if rounds <= 0:
        return RunResult(extract_params(state), {"round": jnp.zeros((0,))},
                         state)
    # eval_every <= 0 with an eval_fn means "evaluate every round" (seed
    # semantics); without an eval_fn all rounds are one dispatch.
    chunk = (max(1, eval_every) if eval_fn is not None else rounds)
    sizes = chunk_sizes(rounds, chunk)

    hist: dict = {"round": []}
    per_round: list = []
    t0 = t_start
    for size in sizes:
        key, sub = jax.random.split(key)
        inputs = make_inputs(fl, t0, size, sub)
        if obs is not None:
            state, ms = obs.run(step_fn, state, inputs, driver=driver)
        else:
            state, ms = engine(step_fn, state, inputs)
        t0 += size
        per_round.append(ms)
        if eval_fn is not None:
            metrics = eval_fn(extract_params(state), state)
            _check_eval_keys(metrics, per_round[0])
            for k, v in metrics.items():
                hist.setdefault(k, []).append(v)
            hist["round"].append(t0 - t_start)
            if obs is not None:
                _emit_eval(obs, metrics, t0 - 1)
    history = {k: jnp.asarray(v) for k, v in hist.items()}
    if per_round and per_round[0]:
        for k in per_round[0]:
            history["round_" + k] = jnp.concatenate([m[k] for m in per_round])
        history["round_t"] = jnp.arange(t_start, t0)
    return RunResult(extract_params(state), history, state)


def run_feature_rounds(step_fn: Callable, state, fl, key, rounds: int,
                       eval_fn: Optional[Callable] = None,
                       eval_every: int = 0,
                       extract_params: Optional[Callable] = None,
                       t_start: int = 1, driver: str = "scan",
                       topology=None, obs=None) -> RunResult:
    """Feature-based (vertical FL, Algorithms 3/4) counterpart of
    :func:`run_rounds`: K vertical rounds — h-exchange, head + block
    q-uploads, 1/B aggregation (eq. 16), SSCA update — compile to ONE
    dispatch, with the codec/EF state riding the scan carry.

    The only difference from `run_rounds` is carry placement: a feature
    CommCarry's EF state is a *dict* of streams, and
    ``topology.place_feature_state`` shards the per-client block residuals
    (I, Pb) over the client axes while the single head stream stays
    replicated — matching `feature_sum`'s out_specs so the carry never
    reshards across the K scanned rounds.
    """
    if topology is not None:
        place = getattr(topology, "place_feature_state", None)
        if place is not None:
            state = place(state)
    return run_rounds(step_fn, state, fl, key, rounds, eval_fn=eval_fn,
                      eval_every=eval_every, extract_params=extract_params,
                      t_start=t_start, driver=driver, obs=obs)

"""Federated protocol layer: client data containers, per-round uploads
(q-statistics), aggregation with N_i/(BN) weights, and communication-load
accounting (Fig. 3's x/y axes).

The privacy mechanism of the paper is *model aggregation*: only B-summed
statistics (q vectors) ever leave a client. The round functions below return
an `uploads` structure so tests can assert exactly what crossed the boundary.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp


class SampleFedData(NamedTuple):
    """Sample-based (horizontal) FL: client i holds rows N_i. Ragged client
    datasets are stored padded to max N_i; `counts` carries the true N_i."""
    features: jnp.ndarray     # (I, N_max, P)
    labels: jnp.ndarray       # (I, N_max, L) one-hot
    counts: jnp.ndarray       # (I,) true N_i

    @property
    def num_clients(self):
        return self.features.shape[0]

    @property
    def total(self):
        return jnp.sum(self.counts)


class FeatureFedData(NamedTuple):
    """Feature-based (vertical) FL: client i holds feature block P_i (equal
    sizes; pad features if needed) and the shared labels."""
    feature_blocks: jnp.ndarray   # (I, N, P_i)
    labels: jnp.ndarray           # (N, L)

    @property
    def num_clients(self):
        return self.feature_blocks.shape[0]

    @property
    def total(self):
        return self.feature_blocks.shape[1]


def partition_samples(features, labels, num_clients, key=None) -> SampleFedData:
    """Split N samples into I (near-)equal client shards."""
    n = features.shape[0]
    if key is not None:
        perm = jax.random.permutation(key, n)
        features, labels = features[perm], labels[perm]
    per = n // num_clients
    features = features[: per * num_clients].reshape(num_clients, per, -1)
    labels = labels[: per * num_clients].reshape(num_clients, per, -1)
    counts = jnp.full((num_clients,), per, jnp.int32)
    return SampleFedData(features, labels, counts)


def partition_features(features, labels, num_clients) -> FeatureFedData:
    """Split the P feature columns into I equal blocks (pad with zero cols)."""
    n, p = features.shape
    per = -(-p // num_clients)   # ceil
    pad = per * num_clients - p
    if pad:
        features = jnp.pad(features, ((0, 0), (0, pad)))
    blocks = features.reshape(n, num_clients, per).transpose(1, 0, 2)
    return FeatureFedData(blocks, labels)


# ---------------------------------------------------------------------------
# sample-based rounds (Algorithm 1/2 steps 3-4)
# ---------------------------------------------------------------------------


def sample_batches(data: SampleFedData, key, batch_size: int):
    """Step 4: each client randomly selects a mini-batch N_i^(t)."""
    keys = jax.random.split(key, data.num_clients)

    def pick(k, count):
        return jax.random.randint(k, (batch_size,), 0, count)

    return jax.vmap(pick)(keys, data.counts)        # (I, B)


def sample_round(per_sample_loss: Callable, params, data: SampleFedData, key,
                 batch_size: int, with_value: bool = False):
    """Computes client uploads q_i = Σ_{n∈batch} ∇f(ω;x_n) (and Σ f if asked)
    then the server aggregate ĝ = Σ_i N_i/(BN) q_i  (and F̂ likewise).

    Returns (grad_est, value_est, uploads) — `uploads` is everything that
    crossed the client boundary (privacy-surface assertion hook).
    """
    idx = sample_batches(data, key, batch_size)      # (I, B)
    n_total = data.total.astype(jnp.float32)

    def client(feat_i, lab_i, idx_i):
        zb = jnp.take(feat_i, idx_i, axis=0)
        yb = jnp.take(lab_i, idx_i, axis=0)

        def batch_sum_loss(p):
            return jnp.sum(per_sample_loss(p, zb, yb))

        val, q = jax.value_and_grad(batch_sum_loss)(params)
        return q, val

    q, val = jax.vmap(client)(data.features, data.labels, idx)   # pytree (I,...), (I,)
    w = data.counts.astype(jnp.float32) / (batch_size * n_total)  # N_i/(BN)
    grad_est = jax.tree.map(
        lambda u: jnp.tensordot(w, u.astype(jnp.float32), axes=1), q)
    value_est = jnp.dot(w, val)
    uploads = {"q_grad_sums": q, "q_value_sums": val if with_value else None}
    return grad_est, value_est, uploads


# ---------------------------------------------------------------------------
# feature-based rounds (Algorithm 3/4 steps 3-6) — the paper's MLP composition
# ---------------------------------------------------------------------------


def feature_round(params, data: FeatureFedData, key, batch_size: int,
                  head_loss_from_h: Callable, client_h: Callable):
    """Faithful Alg-3 information flow for f(ω;x) = g0(ω0, Σ_i h_i(ω_i, x_i)):

      server picks N^(t)  →  client i computes h_i and broadcasts it  →
      any client computes q_{f,0,0} = Σ_n ∇_{ω0} f  →  each client i computes
      q_{f,0,i} = Σ_n ∇_{ω_i} f from (ω0, its block, all h_j)  →  server
      aggregates with 1/B weights (eq. 16).

    params: {"w0": head params, "blocks": (I, ...) client blocks}.
    Returns (grad_est pytree like params, value_est, uploads).
    """
    n = data.total
    idx = jax.random.randint(key, (batch_size,), 0, n)            # server-chosen
    yb = jnp.take(data.labels, idx, axis=0)
    zb = jnp.take(data.feature_blocks, idx, axis=1)               # (I, B, P_i)

    # step 4: h-exchange — client i computes h_i on its block
    h = jax.vmap(client_h)(params["blocks"], zb)                  # (I, B, J)
    h_sum = jnp.sum(h, axis=0)

    # step 5: q_{f,0,0} — head gradient from aggregated h only
    def head_sum_loss(w0, h_sum_):
        return jnp.sum(head_loss_from_h(w0, h_sum_, yb))

    val, q00 = jax.value_and_grad(head_sum_loss)(params["w0"], h_sum)

    # step 6: q_{f,0,i} — via chain rule through client i's own h_i
    dl_dh = jax.grad(lambda hs: head_sum_loss(params["w0"], hs))(h_sum)  # (B, J)

    def block_grad(block_i, zb_i):
        _, vjp = jax.vjp(lambda bl: client_h(bl, zb_i), block_i)
        return vjp(dl_dh)[0]

    q0i = jax.vmap(block_grad)(params["blocks"], zb)              # (I, ...)

    grad_est = {"w0": q00 / batch_size,
                "blocks": q0i / batch_size}
    value_est = val / batch_size
    uploads = {"h_exchange": h, "q_head": q00, "q_blocks": q0i}
    return grad_est, value_est, uploads


def comm_load_per_round(mode: str, d: int, d_blocks: Sequence[int] = (),
                        batch_size: int = 0, h_dim: int = 0,
                        num_clients: int = 0, num_constraints: int = 0):
    """Floats communicated per round (paper's per-round load accounting).

    sample-based (Alg 1/2): each client uploads d (+M·(1+d)); server broadcasts d.
    feature-based (Alg 3/4): h-exchange B·H·I·(I-1) between clients, block
    gradients d_i up, broadcast d down.
    """
    m = num_constraints
    if mode == "sample":
        up = num_clients * (d + m * (1 + d))
        down = num_clients * d
        return {"up": up, "down": down, "total": up + down}
    h_x = batch_size * h_dim * num_clients * (num_clients - 1) * (1 + m)
    up = sum(d_blocks) * (1 + m) + (d - sum(d_blocks)) * (1 + m) + m * num_clients
    down = num_clients * d
    return {"up": up, "down": down, "h_exchange": h_x,
            "total": up + down + h_x}

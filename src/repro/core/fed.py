"""Federated protocol layer: client data containers, per-round uploads
(q-statistics), aggregation with N_i/(BN) weights, and communication-load
accounting (Fig. 3's x/y axes).

The privacy mechanism of the paper is *model aggregation*: only B-summed
statistics (q vectors) ever leave a client. The round functions below return
an `uploads` structure so tests can assert exactly what crossed the boundary.

Both round functions take an optional ``codec=`` (repro.comm.codecs): each
client's flat q-upload is then lossily compressed (with per-client error
feedback when an ``ef`` residual is threaded in) before the server decodes
and aggregates — what crosses the boundary is the codec's wire format, and
``uploads`` exposes it plus the updated residuals and the exact wire bytes
(repro.comm.accounting). Byte-level Fig.-3 bookkeeping lives in
``repro.comm.accounting``; the float counters are re-exported below.

``sample_round`` additionally takes ``topology=`` (repro.core.topology,
DESIGN.md §11), selecting whether its clients run under a single-device vmap
or device-sharded over the mesh via shard_map with the aggregation as a
weighted psum — same math, same uploads surface, same wire bytes.

``cohort_round`` is the participant-only realization of the same protocol
(DESIGN.md §14): instead of computing every client and zero-masking the
non-participants server-side, it draws the S-client cohort in O(S) work
(``cohort_sample``, a keyed Feistel permutation over the virtual population
— no length-I permutation, no dense mask), gathers only the cohort's data
and error-feedback residuals, and runs client compute / codec encode / the
weighted aggregation over the (S, ...) cohort axis. Per-round compute and
carried state scale with S, not I; the unbiased I/S Horvitz-Thompson
reweighting of eq. (9) is preserved, and at small I the trajectory matches
``sample_round`` on the same keys (atol 1e-5 — reassociation only).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.comm import accounting as comm_accounting
from repro.comm import codecs as comm_codecs
from repro.core import topology as topology_lib
from repro.obs import trace as obs_trace


class SampleFedData(NamedTuple):
    """Sample-based (horizontal) FL: client i holds rows N_i. Ragged client
    datasets are stored padded to max N_i; `counts` carries the true N_i."""
    features: jnp.ndarray     # (I, N_max, P)
    labels: jnp.ndarray       # (I, N_max, L) one-hot
    counts: jnp.ndarray       # (I,) true N_i

    @property
    def num_clients(self):
        return self.features.shape[0]

    @property
    def total(self):
        return jnp.sum(self.counts)

    # -- cohort-engine data view (DESIGN.md §14) ---------------------------
    # The O(S) cohort engine never touches the population axis: it asks the
    # data container for exactly the cohort's slice. A virtual population
    # (data/synthetic.VirtualFedData) implements the same three methods by
    # GENERATING the slice from (base key, client id) instead of gathering.

    def counts_for(self, ids):
        """(S,) true N_i for the given client ids."""
        return jnp.take(self.counts, ids, axis=0)

    def batch_rows(self, ids, idx):
        """Cohort mini-batches: (S,) ids + (S, B) in-shard row indices ->
        ((S, B, P) features, (S, B, L) labels). Row values are identical to
        ``take(features[i], idx_i)`` on the dense shard."""
        return (self.features[ids[:, None], idx],
                self.labels[ids[:, None], idx])

    def shards_for(self, ids):
        """Full padded shards for the cohort: ((S, N_max, P), (S, N_max, L),
        (S,) counts) — for drivers whose clients loop over local batches
        (baselines.sample_sgd, local_updates)."""
        return (jnp.take(self.features, ids, axis=0),
                jnp.take(self.labels, ids, axis=0),
                jnp.take(self.counts, ids, axis=0))


class FeatureFedData(NamedTuple):
    """Feature-based (vertical) FL: client i holds feature block P_i (equal
    sizes; pad features if needed) and the shared labels."""
    feature_blocks: jnp.ndarray   # (I, N, P_i)
    labels: jnp.ndarray           # (N, L)

    @property
    def num_clients(self):
        return self.feature_blocks.shape[0]

    @property
    def total(self):
        return self.feature_blocks.shape[1]


def partition_samples(features, labels, num_clients, key=None) -> SampleFedData:
    """Split N samples into I (near-)equal client shards."""
    n = features.shape[0]
    if key is not None:
        perm = jax.random.permutation(key, n)
        features, labels = features[perm], labels[perm]
    per = n // num_clients
    features = features[: per * num_clients].reshape(num_clients, per, -1)
    labels = labels[: per * num_clients].reshape(num_clients, per, -1)
    counts = jnp.full((num_clients,), per, jnp.int32)
    return SampleFedData(features, labels, counts)


def partition_ragged(feature_shards, label_shards) -> SampleFedData:
    """Build a padded SampleFedData from explicit per-client shards (lists of
    (N_i, P) / (N_i, L) arrays with heterogeneous N_i). Padding rows are zero
    and never selected: `sample_batches` draws indices in [0, N_i)."""
    import numpy as np

    counts = np.asarray([len(f) for f in feature_shards], np.int32)
    if (counts <= 0).any():
        raise ValueError(f"every client needs >= 1 sample, got counts={counts}")
    n_max = int(counts.max())
    p = np.asarray(feature_shards[0]).shape[-1]
    l = np.asarray(label_shards[0]).shape[-1]
    feats = np.zeros((len(counts), n_max, p), np.asarray(feature_shards[0]).dtype)
    labs = np.zeros((len(counts), n_max, l), np.asarray(label_shards[0]).dtype)
    for i, (f, y) in enumerate(zip(feature_shards, label_shards)):
        feats[i, : counts[i]] = np.asarray(f)
        labs[i, : counts[i]] = np.asarray(y)
    return SampleFedData(jnp.asarray(feats), jnp.asarray(labs),
                         jnp.asarray(counts))


def partition_dirichlet(features, labels, num_clients, key,
                        alpha: float = 0.5) -> SampleFedData:
    """Non-IID label-skew partition: for each class c, client shares of the
    class-c samples are drawn ~ Dirichlet(alpha·1_I), the standard statistical-
    heterogeneity benchmark protocol. Every sample is assigned to exactly one
    client; N_i become genuinely ragged. alpha → ∞ recovers IID; alpha → 0
    gives near single-class clients. A client that ends up empty is given one
    sample from the largest client (N_i >= 1 is a protocol invariant)."""
    import numpy as np

    lab_int = np.asarray(jnp.argmax(labels, axis=-1))
    features, labels = np.asarray(features), np.asarray(labels)
    num_classes = labels.shape[-1]
    shards = [[] for _ in range(num_clients)]
    for c in range(num_classes):
        idx = np.flatnonzero(lab_int == c)
        if idx.size == 0:
            continue
        kc = jax.random.fold_in(key, c)
        idx = idx[np.asarray(jax.random.permutation(kc, idx.size))]
        props = np.asarray(jax.random.dirichlet(
            jax.random.fold_in(kc, 1), alpha * jnp.ones((num_clients,))))
        # largest-remainder rounding so the splits sum exactly to idx.size
        raw = props * idx.size
        take = np.floor(raw).astype(int)
        rem = idx.size - take.sum()
        take[np.argsort(raw - np.floor(raw))[::-1][:rem]] += 1
        for i, chunk in enumerate(np.split(idx, np.cumsum(take)[:-1])):
            shards[i].extend(chunk.tolist())
    for i in range(num_clients):            # enforce N_i >= 1
        if not shards[i]:
            donor = max(range(num_clients), key=lambda j: len(shards[j]))
            shards[i].append(shards[donor].pop())
    return partition_ragged([features[s] for s in shards],
                            [labels[s] for s in shards])


def partition_features(features, labels, num_clients) -> FeatureFedData:
    """Split the P feature columns into I equal blocks (pad with zero cols)."""
    n, p = features.shape
    per = -(-p // num_clients)   # ceil
    pad = per * num_clients - p
    if pad:
        features = jnp.pad(features, ((0, 0), (0, pad)))
    blocks = features.reshape(n, num_clients, per).transpose(1, 0, 2)
    return FeatureFedData(blocks, labels)


# ---------------------------------------------------------------------------
# shared codec-argument validation — sample_round and feature_round fail
# identically (same messages, same conditions); tests/test_feature_topology.py
# pins the parity
# ---------------------------------------------------------------------------


def _check_codec_args(round_name: str, codec, ef):
    """Reject EF residuals without a codec in BOTH round functions (silently
    ignoring them would drop the caller's error-feedback state)."""
    if codec is None and ef is not None:
        raise ValueError(
            f"{round_name}: error-feedback residuals (ef=) were passed "
            "without codec= — EF is only meaningful for a lossy codec; "
            "pass codec= or drop ef=")


def _check_ef_shape(round_name: str, stream: str, residual, expected_shape):
    """Shape-check one EF residual stream against the upload it feeds, with
    the same message format for both round functions."""
    if residual is None:
        return
    if not hasattr(residual, "shape") or tuple(residual.shape) != tuple(
            expected_shape):
        got = tuple(residual.shape) if hasattr(residual, "shape") else type(
            residual).__name__
        raise ValueError(
            f"{round_name}: error-feedback residuals for stream "
            f"'{stream}' have shape {got}, expected {tuple(expected_shape)} "
            "— rebuild the residual state with the matching "
            "repro.comm.error_feedback ef_init helper")


# ---------------------------------------------------------------------------
# O(S) cohort sampling: keyed Feistel permutation over the virtual population
# ---------------------------------------------------------------------------


def _feistel_mix(x):
    """murmur3 finalizer on uint32 — the Feistel round function's hash."""
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    return x ^ (x >> jnp.uint32(16))


def _feistel(x, round_keys, hi_bits: int, lo_bits: int):
    """Alternating (unbalanced) keyed Feistel network: a bijection on
    [0, 2^(hi_bits+lo_bits)) for ANY round function — each round modularly
    adds a hash of one half to the other, which is invertible regardless of
    the hash. The unbalanced split lets the domain be 2^ceil(log2 I) rather
    than the next even power of two, so cycle-walking rejects < 50% of
    values at every population size (a balanced network's domain can
    overshoot I by almost 4x, tripling the expected walk length)."""
    lo_mask = jnp.uint32((1 << lo_bits) - 1)
    hi_mask = jnp.uint32((1 << hi_bits) - 1)
    hi, lo = x >> lo_bits, x & lo_mask
    for r in range(round_keys.shape[0]):
        if r % 2 == 0:
            lo = (lo + _feistel_mix(hi ^ round_keys[r])) & lo_mask
        else:
            hi = (hi + _feistel_mix(lo ^ round_keys[r])) & hi_mask
    return (hi << lo_bits) | lo


_FEISTEL_ROUNDS = 6
_FEISTEL_MIN_BITS = 8         # >= 8-bit domain: better mixing for tiny I


def cohort_sample(key, num_clients: int, cohort: int):
    """Draw S = `cohort` client ids uniformly without replacement from a
    population of `num_clients` in O(S) work — no length-I permutation.

    The keyed Feistel permutation π is a bijection on the power-of-two
    domain 2^ceil(log2 I) >= I; the cohort is {walk(π(0)), ..., walk(π(S-1))}
    where `walk` cycle-walks π until the value lands inside [0, I) (expected
    < 2 steps: the domain is < 2·I). A fresh key gives an independent
    pseudorandom permutation, so each client appears in the cohort w.p.
    exactly S/I (pinned statistically in tests/test_cohort.py). This is what
    lets the participation draw — and everything keyed off it — scale with
    the cohort instead of the population (DESIGN.md §14).
    """
    if not 1 <= cohort <= num_clients:
        raise ValueError(f"cohort must be in [1, {num_clients}], got {cohort}")
    bits = max(_FEISTEL_MIN_BITS, max(num_clients - 1, 1).bit_length())
    lo_bits, hi_bits = bits // 2, bits - bits // 2
    round_keys = jax.random.bits(key, (_FEISTEL_ROUNDS,), jnp.uint32)
    n = jnp.uint32(num_clients)

    def perm(x):
        return _feistel(x, round_keys, hi_bits, lo_bits)

    def one(i):
        return jax.lax.while_loop(lambda x: x >= n, perm, perm(i))

    ids = jax.vmap(one)(jnp.arange(cohort, dtype=jnp.uint32))
    return ids.astype(jnp.int32)


def client_keys(key, ids):
    """Per-client PRNG keys keyed by STABLE client id (fold_in, not split):
    the dense engine (ids = arange(I)) and the cohort engine (ids = the S
    drawn ids) derive the identical key for the same client, which is what
    makes their trajectories comparable round for round."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(ids)


# ---------------------------------------------------------------------------
# sample-based rounds (Algorithm 1/2 steps 3-4)
# ---------------------------------------------------------------------------


def sample_batches(data: SampleFedData, key, batch_size: int):
    """Step 4: each client randomly selects a mini-batch N_i^(t). Keys are
    derived per client id (`client_keys`) so the cohort engine draws the
    same batch for the same client."""
    keys = client_keys(key, jnp.arange(data.num_clients))

    def pick(k, count):
        return jax.random.randint(k, (batch_size,), 0, count)

    return jax.vmap(pick)(keys, data.counts)        # (I, B)


def batch_mask(counts, batch_size: int):
    """(I, B) validity mask for ragged clients: client i fills min(B, N_i)
    batch slots; a client with N_i < B contributes a smaller sum (its
    aggregation weight uses B_i = min(B, N_i), see `aggregation_weights`).
    For B <= min_i N_i this is all-ones and the dense path is recovered
    bit-for-bit."""
    b_i = jnp.minimum(counts, batch_size)                       # (I,)
    return (jnp.arange(batch_size)[None, :] < b_i[:, None]).astype(jnp.float32)


def participation_mask(key, num_clients: int, participation: int):
    """0/1 mask selecting S = `participation` of I clients uniformly without
    replacement (each client included w.p. S/I).

    The selection is ``cohort_sample`` — O(S) RNG work, not the former
    O(I log I) full permutation — scattered into a dense mask. The dense
    engine and the cohort engine therefore draw the SAME S clients from the
    same key, which is what makes their trajectories comparable."""
    sel = cohort_sample(key, num_clients, participation)
    return jnp.zeros((num_clients,), jnp.float32).at[sel].set(1.0)


def aggregation_weights(counts, batch_size: int, part_mask=None):
    """Server weights w_i applied to the q-uploads.

    Dense full participation: w_i = N_i/(B_i·N) with B_i = min(B, N_i)
    (the paper's N_i/(BN), generalized to ragged clients). Under partial
    participation (mask m selecting S of I clients) the weights become
    m_i·(I/S)·N_i/(B_i·N) — a Horvitz-Thompson estimator, unbiased because
    E[m_i] = S/I exactly cancels the I/S inflation."""
    counts = counts.astype(jnp.float32)
    b_i = jnp.minimum(counts, batch_size)
    w = counts / (b_i * jnp.sum(counts))
    if part_mask is not None:
        scale = counts.shape[0] / jnp.sum(part_mask)
        w = w * part_mask * scale
    return w


def sample_round(per_sample_loss: Callable, params, data: SampleFedData, key,
                 batch_size: int, with_value: bool = False,
                 participation: int | None = None, participation_key=None,
                 codec=None, ef=None, codec_key=None, topology=None,
                 dp=None, dp_key=None):
    """Computes client uploads q_i = Σ_{n∈batch} ∇f(ω;x_n) (and Σ f if asked)
    then the server aggregate ĝ = Σ_i N_i/(B_i·N) q_i  (and F̂ likewise).

    Ragged clients (N_i < B) contribute masked batches of B_i = min(B, N_i)
    samples. With `participation` = S < I, only S uniformly-drawn clients are
    aggregated this round, reweighted by I/S so the estimate stays unbiased
    (this simulation still *computes* every client's q with static shapes and
    zero-masks the rest at the server; a deployment would skip the work).

    With `codec=` each client flattens its q pytree to one (P,) vector and
    uploads the codec's wire format instead of dense fp32; `ef` is the
    (I, P) error-feedback residual matrix from the previous round (zeros if
    None) and the updated residuals come back as ``uploads["ef"]``.
    Non-participating clients neither upload nor touch their residual.

    ``topology=`` selects WHERE the clients execute (core/topology.py,
    DESIGN.md §11): None/`LocalTopology` vmaps all I clients on one device
    (the reference engine); a `ShardedTopology` distributes them over the
    mesh's client axes via shard_map, with this same aggregation realized as
    a weighted `lax.psum` and the codec/EF roundtrip applied per shard
    *before* the collective. Batch selection, participation draw, and codec
    keys are computed identically for every topology, so trajectories agree
    up to float reassociation.

    With ``dp=`` (a repro.core.privacy.DPConfig) each client's flat
    q-upload is clipped to ``dp.clip_norm`` at B_i-mean scale and Gaussian-
    noised at the analytic σ BEFORE any codec encode (DESIGN.md §15) — the
    wire format, bytes accounting, and EF residual see the privatized
    upload, and under a sharded topology the noise is added per shard
    before the psum. Noise keys derive from the STABLE client id
    (`client_keys`), so the dense and cohort engines draw identical noise
    for the same client; ``dp_key`` overrides the derivation base.
    Per-client clip/noise statistics come back as ``uploads["dp"]``.

    Returns (grad_est, value_est, uploads) — `uploads` is everything that
    crossed the client boundary (privacy-surface assertion hook); with a
    codec that is ``uploads["encoded"]`` (wire format) and
    ``uploads["upload_nbytes"]`` (exact bytes, repro.comm.accounting).
    """
    if participation is not None and participation < 1:
        raise ValueError(f"participation must be >= 1, got {participation}")
    _check_codec_args("sample_round", codec, ef)
    if codec is not None:
        _check_ef_shape("sample_round", "q_grad", ef,
                        (data.num_clients, comm_codecs.tree_flat_dim(params)))
    topo = topology if topology is not None else topology_lib.LOCAL
    with obs_trace.phase("batch-select"):
        idx = sample_batches(data, key, batch_size)      # (I, B)
        bmask = batch_mask(data.counts, batch_size)      # (I, B)

    def client(feat_i, lab_i, idx_i, mask_i):
        zb = jnp.take(feat_i, idx_i, axis=0)
        yb = jnp.take(lab_i, idx_i, axis=0)

        def batch_sum_loss(p):
            return jnp.sum(per_sample_loss(p, zb, yb) * mask_i)

        val, q = jax.value_and_grad(batch_sum_loss)(params)
        return q, val

    pmask = None
    # S >= I degrades to full participation (the I/S reweighting is exactly 1)
    if participation is not None and participation < data.num_clients:
        if participation_key is None:
            participation_key = jax.random.fold_in(key, 0x5ca)
        pmask = participation_mask(participation_key, data.num_clients,
                                   participation)
    ckeys = active = None
    nbytes = None
    if codec is not None:
        if codec_key is None:
            codec_key = jax.random.fold_in(key, 0xC0DEC)
        ckeys = client_keys(codec_key, jnp.arange(data.num_clients))
        active = pmask if pmask is not None else jnp.ones((data.num_clients,))
        nbytes = comm_accounting.sample_round_bytes(
            comm_codecs.tree_flat_dim(params), data.num_clients, codec,
            participation=participation, with_value=with_value)["up"]
    dkeys = dscale = None
    if dp is not None:
        if dp_key is None:
            dp_key = jax.random.fold_in(key, 0xD9)
        dkeys = client_keys(dp_key, jnp.arange(data.num_clients))
        # clip at the client's B_i-MEAN scale (C is a per-example-scale
        # constant); the stage rescales to the B_i-sum afterwards so the
        # eq.-(9) weights are untouched
        dscale = 1.0 / jnp.minimum(data.counts.astype(jnp.float32),
                                   float(batch_size))
    w = aggregation_weights(data.counts, batch_size, pmask)
    s = topo.weighted_sum(client, (data.features, data.labels, idx, bmask), w,
                          codec=codec, ef=ef, codec_keys=ckeys, active=active,
                          dp=dp, dp_keys=dkeys, dp_scale=dscale)
    uploads = {"q_grad_sums": s.uploads,
               "q_value_sums": s.values if with_value else None,
               "participants": pmask, "encoded": s.encoded, "ef": s.ef,
               "dp": s.dp, "upload_nbytes": nbytes}
    return s.weighted, s.value, uploads


def cohort_weights(counts_s, batch_size: int, num_clients: int, total):
    """Horvitz-Thompson server weights for the S-client cohort:
    w_i = (I/S)·N_i/(B_i·N). Identical numbers to the non-zero entries of
    ``aggregation_weights(counts, B, pmask)`` on the dense path — the cohort
    engine just never materializes the zeros."""
    counts_s = counts_s.astype(jnp.float32)
    b_i = jnp.minimum(counts_s, batch_size)
    scale = num_clients / counts_s.shape[0]
    return scale * counts_s / (b_i * total)


def cohort_round(per_sample_loss: Callable, params, data, key,
                 batch_size: int, cohort: int, with_value: bool = False,
                 participation_key=None, codec=None, ef=None, codec_key=None,
                 topology=None, dp=None, dp_key=None):
    """Participant-only O(S) realization of :func:`sample_round` under
    partial participation (DESIGN.md §14).

    Where ``sample_round(participation=S)`` computes all I clients and
    zero-masks I−S of them server-side, this draws the S-client cohort in
    O(S) work (`cohort_sample`), gathers ONLY the cohort's data shards
    (``data.batch_rows`` — a `SampleFedData` gathers rows, a
    `data.synthetic.VirtualFedData` generates them from the client id, so
    I = 1e6 never materializes anything population-sized), and runs client
    compute, codec encode, and the eq.-(9) weighted aggregation over the
    (S, ...) cohort axis. Per-round compute and carried state scale with S.

    Equality contract (pinned in tests/test_cohort.py and
    benchmarks/scale_bench.py): with the same `key`/`participation_key`/
    `codec_key`, the same clients are drawn (`participation_mask` scatters
    the same `cohort_sample` ids), each drawn client derives the same batch
    and codec keys (`client_keys` folds in the stable client id), and the
    Horvitz-Thompson weights match the dense masked weights entry-for-entry
    — so grad/value estimates agree with the dense engine up to float
    reassociation (atol 1e-5: an S-term sum vs an I-term sum with zeros).

    ``ef`` is a :class:`repro.comm.error_feedback.EFStore` holding the
    (I, P) residual backing; only the cohort's (S, P) slice is gathered
    into the round and scattered back — non-participants' residuals are
    never touched (bit-frozen by construction, not by masking). The updated
    store comes back as ``uploads["ef"]``.

    ``topology=`` shards the COHORT axis: a `ShardedTopology` splits the S
    participants over the mesh (S must divide by the shard count), so
    population size never constrains the mesh fit.

    ``dp=`` privatizes the cohort's uploads exactly as in
    :func:`sample_round` — O(S) clip+noise work with noise keys derived
    from the STABLE client id, so the dense engine's noise for the same
    drawn client is identical and the two trajectories keep agreeing at
    atol 1e-5. The S-of-I draw is also what earns the accountant's
    subsampling amplification (privacy.rdp_per_round at q = S/I).
    Per-client stats come back as ``uploads["dp"]`` ((S,)-shaped).

    Returns (grad_est, value_est, uploads); ``uploads["cohort"]`` is the
    (S,) drawn client ids — the O(S) analog of the dense path's
    ``uploads["participants"]`` mask.
    """
    _check_codec_args("cohort_round", codec, ef)
    topo = topology if topology is not None else topology_lib.LOCAL
    num_clients = data.num_clients
    if participation_key is None:
        participation_key = jax.random.fold_in(key, 0x5ca)
    with obs_trace.phase("cohort-select"):
        ids = cohort_sample(participation_key, num_clients, cohort)   # (S,)
        counts_s = data.counts_for(ids)                               # (S,)
    with obs_trace.phase("batch-select"):
        bkeys = client_keys(key, ids)
        idx = jax.vmap(
            lambda k, c: jax.random.randint(k, (batch_size,), 0, c)
        )(bkeys, counts_s)                                            # (S, B)
        bmask = batch_mask(counts_s, batch_size)                      # (S, B)
        zb, yb = data.batch_rows(ids, idx)            # (S, B, P), (S, B, L)

    def client(zb_i, yb_i, mask_i):
        def batch_sum_loss(p):
            return jnp.sum(per_sample_loss(p, zb_i, yb_i) * mask_i)

        val, q = jax.value_and_grad(batch_sum_loss)(params)
        return q, val

    ckeys = active = ef_rows = None
    nbytes = None
    if codec is not None:
        dim = comm_codecs.tree_flat_dim(params)
        if ef is not None:
            if not hasattr(ef, "gather"):
                raise ValueError(
                    "cohort_round: ef must be a keyed "
                    "repro.comm.error_feedback.EFStore (ef_store_init), not "
                    f"a dense residual array — got {type(ef).__name__}")
            _check_ef_shape("cohort_round", "q_grad", ef.data,
                            (num_clients, dim))
            ef_rows = ef.gather(ids)                                  # (S, P)
        if codec_key is None:
            codec_key = jax.random.fold_in(key, 0xC0DEC)
        ckeys = client_keys(codec_key, ids)
        active = jnp.ones((cohort,), jnp.float32)
        nbytes = comm_accounting.sample_round_bytes(
            dim, num_clients, codec, participation=cohort,
            with_value=with_value)["up"]
    dkeys = dscale = None
    if dp is not None:
        if dp_key is None:
            dp_key = jax.random.fold_in(key, 0xD9)
        dkeys = client_keys(dp_key, ids)      # stable ids == dense engine
        dscale = 1.0 / jnp.minimum(counts_s.astype(jnp.float32),
                                   float(batch_size))
    w = cohort_weights(counts_s, batch_size, num_clients, data.total)
    s = topo.weighted_sum(client, (zb, yb, bmask), w, codec=codec,
                          ef=ef_rows, codec_keys=ckeys, active=active,
                          dp=dp, dp_keys=dkeys, dp_scale=dscale)
    new_ef = ef.scatter(ids, s.ef) if (codec is not None
                                       and ef is not None) else s.ef
    uploads = {"q_grad_sums": s.uploads,
               "q_value_sums": s.values if with_value else None,
               "cohort": ids, "encoded": s.encoded, "ef": new_ef,
               "dp": s.dp, "upload_nbytes": nbytes}
    return s.weighted, s.value, uploads


# ---------------------------------------------------------------------------
# feature-based rounds (Algorithm 3/4 steps 3-6) — the paper's MLP composition
# ---------------------------------------------------------------------------


def feature_round(params, data: FeatureFedData, key, batch_size: int,
                  head_loss_from_h: Callable, client_h: Callable,
                  codec=None, ef=None, codec_key=None, topology=None,
                  dp=None, dp_key=None):
    """Faithful Alg-3 information flow for f(ω;x) = g0(ω0, Σ_i h_i(ω_i, x_i)):

      server picks N^(t)  →  client i computes h_i and broadcasts it  →
      any client computes q_{f,0,0} = Σ_n ∇_{ω0} f  →  each client i computes
      q_{f,0,i} = Σ_n ∇_{ω_i} f from (ω0, its block, all h_j)  →  server
      aggregates with 1/B weights (eq. 16).

    params: {"w0": head params, "blocks": (I, ...) client blocks}.
    With `codec=` the q_{f,0,0} head upload and each client's q_{f,0,i}
    block upload cross the wire compressed, with error-feedback residuals
    ``ef = {"w0": (P0,), "blocks": (I, Pb)}`` (the step-4 h-exchange stays
    dense — it feeds gradients, not the aggregate, and is accounted in
    repro.comm.accounting.feature_round_bytes).

    ``topology=`` selects WHERE the feature clients execute (DESIGN.md §12):
    None/`LocalTopology` vmaps all I clients on one device (the reference
    engine); a `ShardedTopology` built over a "model"-axis mesh
    (`launch.mesh.make_feature_mesh`) places each client on its own shard,
    with the h-exchange realized as a tiled `lax.all_gather` — bit-identical
    h_sum, hence bit-identical gradients and wire formats across topologies.
    Batch selection and codec keys are computed identically for every
    topology.

    With ``dp=`` the two q-upload streams — the head q_{f,0,0} and each
    client's block q_{f,0,i} — are clipped at B-mean scale and Gaussian-
    noised BEFORE any codec encode, exactly as in :func:`sample_round`
    (DESIGN.md §15). The step-4 h-exchange is NOT privatized here: it is a
    per-round activation broadcast, not an aggregate release, and a
    deployment would need a separate mechanism for it (documented
    limitation). Per-stream stats come back as ``uploads["dp"]``.

    Returns (grad_est pytree like params, value_est, uploads).
    """
    _check_codec_args("feature_round", codec, ef)
    topo = topology if topology is not None else topology_lib.LOCAL
    n = data.total
    with obs_trace.phase("batch-select"):
        idx = jax.random.randint(key, (batch_size,), 0, n)        # server-chosen
        yb = jnp.take(data.labels, idx, axis=0)
        zb = jnp.take(data.feature_blocks, idx, axis=1)           # (I, B, P_i)

    def head_sum_loss(w0, h_sum_):
        return jnp.sum(head_loss_from_h(w0, h_sum_, yb))

    # step 5: q_{f,0,0} — head gradient from aggregated h only; the closure
    # over (params["w0"], yb) is replicated compute under a sharded topology
    def head_fn(h_sum):
        val, q00 = jax.value_and_grad(head_sum_loss)(params["w0"], h_sum)
        # step 6's upstream: dl/dh backpropagated through the aggregate
        dl_dh = jax.grad(lambda hs: head_sum_loss(params["w0"], hs))(h_sum)
        return val, q00, dl_dh

    # step 6: q_{f,0,i} — via chain rule through client i's own h_i
    def block_grad(block_i, zb_i, dl_dh):
        _, vjp = jax.vjp(lambda bl: client_h(bl, zb_i), block_i)
        return vjp(dl_dh)[0]

    head_key = block_keys = None
    nbytes = None
    d_head = d_block = None
    if codec is not None:
        d_head = comm_codecs.tree_flat_dim(params["w0"])
        d_block = comm_codecs.tree_flat_dim(params["blocks"], stacked=True)
        if ef is not None:
            if not isinstance(ef, dict) or set(ef) != {"w0", "blocks"}:
                raise ValueError(
                    "feature_round: ef must be a dict with 'w0' and 'blocks' "
                    f"residual streams (repro.comm ef_init/ef_init_stacked), "
                    f"got {sorted(ef) if isinstance(ef, dict) else type(ef).__name__}")
            _check_ef_shape("feature_round", "w0", ef["w0"], (d_head,))
            _check_ef_shape("feature_round", "blocks", ef["blocks"],
                            (data.num_clients, d_block))
        if codec_key is None:
            codec_key = jax.random.fold_in(key, 0xC0DEC)
        head_key = jax.random.fold_in(codec_key, 0)
        block_keys = client_keys(jax.random.fold_in(codec_key, 1),
                                 jnp.arange(data.num_clients))
    dp_head_key = dp_block_keys = None
    if dp is not None:
        if dp_key is None:
            dp_key = jax.random.fold_in(key, 0xD9)
        dp_head_key = jax.random.fold_in(dp_key, 0)
        dp_block_keys = client_keys(jax.random.fold_in(dp_key, 1),
                                    jnp.arange(data.num_clients))

    s = topo.feature_sum(client_h, head_fn, block_grad, params["blocks"], zb,
                         codec=codec, ef=ef, head_key=head_key,
                         block_keys=block_keys, dp=dp,
                         dp_head_key=dp_head_key, dp_block_keys=dp_block_keys,
                         dp_scale=1.0 / batch_size)
    if codec is not None:
        nbytes = comm_accounting.feature_round_bytes(
            d_head, [d_block] * data.num_clients, batch_size,
            s.h.shape[-1], data.num_clients, codec)["up"]

    grad_est = {"w0": s.q_head / batch_size,
                "blocks": s.q_blocks / batch_size}
    value_est = s.value / batch_size
    uploads = {"h_exchange": s.h, "q_head": s.q_head, "q_blocks": s.q_blocks,
               "encoded": s.encoded, "ef": s.ef, "dp": s.dp,
               "upload_nbytes": nbytes}
    return grad_est, value_est, uploads


# Fig.-3 float counters: moved to repro.comm.accounting (which adds the
# byte-level, codec-aware versions); re-exported here for back-compat.
comm_load_per_round = comm_accounting.comm_load_per_round

"""Baseline FL algorithms the paper compares against (§VI):

  - sample-based SGD  [5],[6]: E local SGD steps per round, weighted model
    averaging (E=1 & full batch -> FedSGD; B·E = N_i -> FedAvg; E>1 -> PR-SGD)
  - sample-based SGD-m [7]: E local momentum-SGD steps, constant stepsize
  - feature-based SGD / SGD-m [13]: one global step per round using the same
    h-exchange information collection as Algorithm 3

Learning rates follow §VI: SGD r_t = ā/t^ᾱ; SGD-m constant ā, momentum β̄.

Both baselines take ``codec=`` (repro.comm) so the compression comparison is
apples-to-apples with the SSCA drivers: sample-based SGD compresses each
client's *model delta* Δ_i = ω_i^local − ω (the round's upload; the weighted
average Σ w_i(ω + Δ̂_i) = ω + Σ w_i Δ̂_i since Σ w_i = 1), feature-based SGD
compresses the same q-uploads as Algorithm 3 via ``fed.feature_round``.
Error-feedback residuals ride the scan carry in a CommCarry, exactly as in
core/algorithms.py.

``sample_sgd`` also takes ``topology=`` (core/topology.py): its per-client
local-step loop + delta upload + N_i/N weighted averaging run through the
same client-execution engine as the SSCA drivers, so the baseline comparison
stays apples-to-apples on a sharded mesh too.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.comm import accounting as comm_accounting
from repro.comm import codecs as comm_codecs
from repro.comm import error_feedback as comm_ef
from repro.comm.error_feedback import with_comm_carry
from repro.core import fed
from repro.core import topology as topology_lib
from repro.core.algorithms import (RunResult, _check_cohort,
                                   _feature_axis_bytes, _feature_ef0,
                                   _feature_upload_bytes, _run, _run_feature,
                                   _wrap_codec_state)
from repro.core.fed import FeatureFedData, SampleFedData
from repro.core.tree import tree_axpy, tree_l2sq, tree_zeros_like


class SGDConfig(NamedTuple):
    lr_a: float = 0.3          # ā
    lr_alpha: float = 0.3      # ᾱ  (0 -> constant stepsize)
    momentum: float = 0.0      # β̄ (SGD-m)
    local_steps: int = 1       # E
    local_batch: int = 10      # per-local-step batch size
    l2_lambda: float = 1e-5


def _lr(cfg: SGDConfig, t):
    t = jnp.maximum(t, 1).astype(jnp.float32)
    return cfg.lr_a / t**cfg.lr_alpha


class SGDState(NamedTuple):
    params: object
    t: jnp.ndarray


class SGDmState(NamedTuple):
    params: object
    v: object
    t: jnp.ndarray


def _reg_grad(per_sample_loss, lam):
    def f(p, z, y):
        return jnp.mean(per_sample_loss(p, z, y)) + lam * tree_l2sq(p)
    return jax.grad(f)


def sample_sgd(per_sample_loss, params0, data: SampleFedData, cfg: SGDConfig,
               rounds: int, key, eval_fn=None, eval_every: int = 10,
               momentum: bool = False, codec=None, topology=None,
               obs=None, participation=None, cohort: bool = False) -> RunResult:
    """E local (momentum-)SGD steps per client per round + weighted averaging.
    Each client's upload is its model delta Δ_i = ω_i^local − ω (compressed
    when a codec is given); the server applies ω ← ω + Σ_i (N_i/N) Δ̂_i,
    which equals weighted model averaging because Σ_i w_i = 1. The
    client-local steps + delta uploads + weighted sum run through the
    topology engine (core/topology.py), so ``topology=sharded`` distributes
    the E local steps of each client over the mesh like the SSCA drivers.

    ``participation=S`` draws S-of-I clients per round (`fed.cohort_sample`
    under the dense mask), Horvitz-Thompson reweighting the delta average:
    ω ← ω + (I/S)·Σ_{i∈cohort} (N_i/N) Δ̂_i — unbiased for the full-
    participation update since E over cohorts recovers every w_i.
    ``cohort=True`` additionally switches to the participant-only O(S)
    engine (DESIGN.md §14): only the cohort's shards are gathered (or
    generated, for a `VirtualFedData`), EF residuals live in a keyed
    `EFStore`, and the dense trajectory is reproduced to float
    reassociation on the same keys."""
    grad_fn = _reg_grad(per_sample_loss, cfg.l2_lambda)
    topo = topology if topology is not None else topology_lib.LOCAL
    _check_cohort("sample_sgd", cohort, participation)
    num_clients = data.num_clients
    dim = comm_codecs.tree_flat_dim(params0)
    up_bytes = float(comm_accounting.sample_round_bytes(
        dim, num_clients, codec, participation=participation)["up"])

    def local(params_v0, feat_i, lab_i, count_i, k, lr):
        def one(step, carry):
            p, v = carry
            kk = jax.random.fold_in(k, step)
            idx = jax.random.randint(kk, (cfg.local_batch,), 0, count_i)
            g = grad_fn(p, jnp.take(feat_i, idx, 0), jnp.take(lab_i, idx, 0))
            if momentum:
                v = jax.tree.map(lambda vv, gg: cfg.momentum * vv + gg, v, g)
                upd = v
            else:
                upd = g
            p = jax.tree.map(lambda pp, uu: pp - lr * uu, p, upd)
            return p, v

        v0 = tree_zeros_like(params_v0)
        return jax.lax.fori_loop(0, cfg.local_steps, one, (params_v0, v0))

    def body(state, inp, ef):
        lr = cfg.lr_a if momentum else _lr(cfg, state.t)

        def client_fn(f_, l_, c_, k_):
            p_local, _ = local(state.params, f_, l_, c_, k_, lr)
            delta = jax.tree.map(lambda u, p: u - p, p_local, state.params)
            return delta, jnp.zeros((), jnp.float32)

        ck = jax.random.fold_in(inp.key, 0xC0DEC)
        if cohort:
            pk = jax.random.fold_in(inp.key, 0x5ca)
            ids = fed.cohort_sample(pk, num_clients, participation)
            feats, labs, counts_s = data.shards_for(ids)
            keys = fed.client_keys(inp.key, ids)
            w = ((num_clients / participation)
                 * counts_s.astype(jnp.float32) / data.total)
            ckeys = fed.client_keys(ck, ids) if codec is not None else None
            ef_rows = (ef.gather(ids)
                       if codec is not None and ef is not None else None)
            s = topo.weighted_sum(client_fn, (feats, labs, counts_s, keys), w,
                                  codec=codec, ef=ef_rows, codec_keys=ckeys)
            new_ef = (ef.scatter(ids, s.ef)
                      if codec is not None and ef is not None else s.ef)
        else:
            keys = fed.client_keys(inp.key, jnp.arange(num_clients))
            w = data.counts.astype(jnp.float32) / jnp.sum(data.counts)
            active = None
            if participation is not None and participation < num_clients:
                pmask = fed.participation_mask(
                    jax.random.fold_in(inp.key, 0x5ca), num_clients,
                    participation)
                w = w * pmask * (num_clients / jnp.sum(pmask))
                active = pmask
            ckeys = (fed.client_keys(ck, jnp.arange(num_clients))
                     if codec is not None else None)
            s = topo.weighted_sum(
                client_fn, (data.features, data.labels, data.counts, keys),
                w, codec=codec, ef=ef, codec_keys=ckeys, active=active)
            new_ef = s.ef
        params = jax.tree.map(lambda p, d: (p + d).astype(p.dtype),
                              state.params, s.weighted)
        new = SGDState(params=params, t=state.t + 1)
        return new, new_ef, {"upload_bytes": up_bytes}

    state = _wrap_codec_state(
        SGDState(params=params0, t=jnp.ones((), jnp.int32)), codec,
        lambda: (comm_ef.ef_store_init(num_clients, dim) if cohort
                 else comm_ef.ef_init_stacked(num_clients, dim)))
    return _run(with_comm_carry(codec, body), state, key, rounds, eval_fn,
                eval_every, topology=topology, obs=obs)


def feature_sgd(head_loss_from_h, client_h, params0, data: FeatureFedData,
                cfg: SGDConfig, rounds: int, key, eval_fn=None,
                eval_every: int = 10, momentum: bool = False,
                codec=None, topology=None, obs=None) -> RunResult:
    """One global (momentum-)SGD step per round via the Alg-3 info collection
    (codec compresses the same q-uploads as Algorithm 3; topology runs the
    feature clients local or model-axis sharded, DESIGN.md §12)."""
    def body(state, inp, ef):
        if momentum:
            params, v, t = state.params, state.v, state.t
        else:
            params, t = state.params, state.t
        grad_est, _, up = fed.feature_round(
            params, data, inp.key, cfg.local_batch, head_loss_from_h,
            client_h, codec=codec, ef=ef, topology=topology)
        grad_est = jax.tree.map(
            lambda g, p: g + 2 * cfg.l2_lambda * p, grad_est, params)
        lr = cfg.lr_a if momentum else _lr(cfg, t)
        if momentum:
            v = jax.tree.map(lambda vv, gg: cfg.momentum * vv + gg, v, grad_est)
            params = jax.tree.map(lambda p, u: p - lr * u, params, v)
            new = SGDmState(params=params, v=v, t=t + 1)
        else:
            params = jax.tree.map(lambda p, g: p - lr * g, params, grad_est)
            new = SGDState(params=params, t=t + 1)
        metrics = {"upload_bytes": _feature_upload_bytes(
            up, grad_est, data, cfg.local_batch)}
        return new, up["ef"], metrics

    if momentum:
        state = SGDmState(params=params0, v=tree_zeros_like(params0),
                          t=jnp.ones((), jnp.int32))
    else:
        state = SGDState(params=params0, t=jnp.ones((), jnp.int32))
    state = _wrap_codec_state(
        state, codec, lambda: _feature_ef0(params0, data.num_clients))
    return _run_feature(with_comm_carry(codec, body), state, key, rounds,
                        eval_fn, eval_every, topology=topology, obs=obs)


# ---------------------------------------------------------------------------
# constrained vertical-FL baselines (benchmarks/feature_bench.py scenario:
# min ‖ω‖² s.t. F(ω) <= U, the paper's formulation (40) under the Alg-3/4
# feature composition) — both collect the exact same per-round information
# as Algorithm 4 (fed.feature_round: h-exchange + head/block q-uploads), so
# rounds and upload bytes are apples-to-apples; only the update rule differs.
# ---------------------------------------------------------------------------


class FWConfig(NamedTuple):
    """Projection-free federated Frank-Wolfe baseline (after Dadras et al.,
    *Federated Frank-Wolfe Algorithm*): exact-penalty reformulation
    min_{‖ω‖<=R} ‖ω‖² + c·max(0, F̂(ω) − U) over an L2 ball, linear
    minimization oracle s = −R·g/‖g‖, classic step η_t = a/(t+2)."""
    radius: float = 10.0       # feasible-ball radius R (the LMO domain)
    penalty: float = 10.0      # exact-penalty weight c on the hinge
    lr_a: float = 2.0          # η_t = lr_a/(t+2)


def feature_frank_wolfe(head_loss_from_h, client_h, params0,
                        data: FeatureFedData, fl, cfg: FWConfig, rounds: int,
                        key, eval_fn=None, eval_every: int = 10,
                        driver: str = "scan", codec=None,
                        topology=None, obs=None) -> RunResult:
    """ω_{t+1} = (1−η_t)ω_t + η_t·s_t with s_t the L2-ball LMO of the
    penalized subgradient g_t = 2ω_t + c·1[F̂>U]·∇F̂(ω_t). The iterate stays
    inside the ball by convexity, so the method is projection-free; it has
    no dual iterate, so feature_bench scores its KKT stationarity at the
    best-response multiplier (solvers.kkt_best_nu)."""
    def body(state, inp, ef):
        grad_est, val_est, up = fed.feature_round(
            state.params, data, inp.key, fl.batch_size, head_loss_from_h,
            client_h, codec=codec, ef=ef, topology=topology)
        act = (val_est > fl.cost_limit).astype(jnp.float32)
        g = jax.tree.map(lambda p, gf: 2.0 * p + cfg.penalty * act * gf,
                         state.params, grad_est)
        norm = jnp.sqrt(jnp.maximum(tree_l2sq(g), 1e-24))
        s_lmo = jax.tree.map(lambda gg: -cfg.radius * gg / norm, g)
        eta = cfg.lr_a / (state.t.astype(jnp.float32) + 2.0)
        params = jax.tree.map(
            lambda p, s_: ((1.0 - eta) * p + eta * s_).astype(p.dtype),
            state.params, s_lmo)
        new = SGDState(params=params, t=state.t + 1)
        metrics = {"loss_est": val_est,
                   "upload_bytes": _feature_upload_bytes(
                       up, grad_est, data, fl.batch_size),
                   "axis_bytes": _feature_axis_bytes(topology, up)}
        return new, up["ef"], metrics

    state = _wrap_codec_state(
        SGDState(params=params0, t=jnp.ones((), jnp.int32)), codec,
        lambda: _feature_ef0(params0, data.num_clients))
    return _run_feature(with_comm_carry(codec, body), state, key, rounds,
                        eval_fn, eval_every, fl=fl, driver=driver,
                        topology=topology, obs=obs)


class DualConfig(NamedTuple):
    """Dual-decomposition / Arrow-Hurwicz baseline (after Fan et al., *A dual
    approach for federated learning*): alternating primal descent on the
    Lagrangian L(ω,ν) = ‖ω‖² + ν(F̂(ω) − U) and projected dual ascent, both
    with diminishing a/√t stepsizes."""
    lr_primal: float = 0.2
    lr_dual: float = 1.0
    nu_max: float = 1e4        # dual cap, mirrors the SSCA penalty_c role


class DualState(NamedTuple):
    params: object
    nu: jnp.ndarray
    t: jnp.ndarray


def feature_dual_decomposition(head_loss_from_h, client_h, params0,
                               data: FeatureFedData, fl, cfg: DualConfig,
                               rounds: int, key, eval_fn=None,
                               eval_every: int = 10, driver: str = "scan",
                               codec=None, topology=None, obs=None) -> RunResult:
    """ω ← ω − η_ω(2ω + ν∇F̂);  ν ← clip(ν + η_ν(F̂ − U), 0, ν_max). Its ν
    IS a dual iterate, so feature_bench scores its KKT residuals directly."""
    def body(state, inp, ef):
        grad_est, val_est, up = fed.feature_round(
            state.params, data, inp.key, fl.batch_size, head_loss_from_h,
            client_h, codec=codec, ef=ef, topology=topology)
        sqrt_t = jnp.sqrt(state.t.astype(jnp.float32))
        lag = jax.tree.map(lambda p, gf: 2.0 * p + state.nu * gf,
                           state.params, grad_est)
        params = tree_axpy(1.0, state.params, -cfg.lr_primal / sqrt_t, lag)
        params = jax.tree.map(lambda p, p0: p.astype(p0.dtype), params,
                              state.params)
        nu = jnp.clip(state.nu + (cfg.lr_dual / sqrt_t)
                      * (val_est - fl.cost_limit), 0.0, cfg.nu_max)
        new = DualState(params=params, nu=nu, t=state.t + 1)
        metrics = {"loss_est": val_est, "nu": nu,
                   "upload_bytes": _feature_upload_bytes(
                       up, grad_est, data, fl.batch_size),
                   "axis_bytes": _feature_axis_bytes(topology, up)}
        return new, up["ef"], metrics

    state = _wrap_codec_state(
        DualState(params=params0, nu=jnp.zeros((), jnp.float32),
                  t=jnp.ones((), jnp.int32)), codec,
        lambda: _feature_ef0(params0, data.num_clients))
    return _run_feature(with_comm_carry(codec, body), state, key, rounds,
                        eval_fn, eval_every, fl=fl, driver=driver,
                        topology=topology, obs=obs)

"""Baseline FL algorithms the paper compares against (§VI):

  - sample-based SGD  [5],[6]: E local SGD steps per round, weighted model
    averaging (E=1 & full batch -> FedSGD; B·E = N_i -> FedAvg; E>1 -> PR-SGD)
  - sample-based SGD-m [7]: E local momentum-SGD steps, constant stepsize
  - feature-based SGD / SGD-m [13]: one global step per round using the same
    h-exchange information collection as Algorithm 3

Learning rates follow §VI: SGD r_t = ā/t^ᾱ; SGD-m constant ā, momentum β̄.

Both baselines take ``codec=`` (repro.comm) so the compression comparison is
apples-to-apples with the SSCA drivers: sample-based SGD compresses each
client's *model delta* Δ_i = ω_i^local − ω (the round's upload; the weighted
average Σ w_i(ω + Δ̂_i) = ω + Σ w_i Δ̂_i since Σ w_i = 1), feature-based SGD
compresses the same q-uploads as Algorithm 3 via ``fed.feature_round``.
Error-feedback residuals ride the scan carry in a CommCarry, exactly as in
core/algorithms.py.

``sample_sgd`` also takes ``topology=`` (core/topology.py): its per-client
local-step loop + delta upload + N_i/N weighted averaging run through the
same client-execution engine as the SSCA drivers, so the baseline comparison
stays apples-to-apples on a sharded mesh too.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.comm import accounting as comm_accounting
from repro.comm import codecs as comm_codecs
from repro.comm import error_feedback as comm_ef
from repro.comm.error_feedback import with_comm_carry
from repro.core import fed
from repro.core import topology as topology_lib
from repro.core.algorithms import (RunResult, _feature_ef0,
                                   _feature_upload_bytes, _run,
                                   _wrap_codec_state)
from repro.core.fed import FeatureFedData, SampleFedData
from repro.core.tree import tree_l2sq, tree_zeros_like


class SGDConfig(NamedTuple):
    lr_a: float = 0.3          # ā
    lr_alpha: float = 0.3      # ᾱ  (0 -> constant stepsize)
    momentum: float = 0.0      # β̄ (SGD-m)
    local_steps: int = 1       # E
    local_batch: int = 10      # per-local-step batch size
    l2_lambda: float = 1e-5


def _lr(cfg: SGDConfig, t):
    t = jnp.maximum(t, 1).astype(jnp.float32)
    return cfg.lr_a / t**cfg.lr_alpha


class SGDState(NamedTuple):
    params: object
    t: jnp.ndarray


class SGDmState(NamedTuple):
    params: object
    v: object
    t: jnp.ndarray


def _reg_grad(per_sample_loss, lam):
    def f(p, z, y):
        return jnp.mean(per_sample_loss(p, z, y)) + lam * tree_l2sq(p)
    return jax.grad(f)


def sample_sgd(per_sample_loss, params0, data: SampleFedData, cfg: SGDConfig,
               rounds: int, key, eval_fn=None, eval_every: int = 10,
               momentum: bool = False, codec=None, topology=None) -> RunResult:
    """E local (momentum-)SGD steps per client per round + weighted averaging.
    Each client's upload is its model delta Δ_i = ω_i^local − ω (compressed
    when a codec is given); the server applies ω ← ω + Σ_i (N_i/N) Δ̂_i,
    which equals weighted model averaging because Σ_i w_i = 1. The
    client-local steps + delta uploads + weighted sum run through the
    topology engine (core/topology.py), so ``topology=sharded`` distributes
    the E local steps of each client over the mesh like the SSCA drivers."""
    grad_fn = _reg_grad(per_sample_loss, cfg.l2_lambda)
    topo = topology if topology is not None else topology_lib.LOCAL
    w = data.counts.astype(jnp.float32) / jnp.sum(data.counts)
    dim = comm_codecs.tree_flat_dim(params0)
    up_bytes = float(comm_accounting.sample_round_bytes(
        dim, data.num_clients, codec)["up"])

    def local(params_v0, feat_i, lab_i, count_i, k, lr):
        def one(step, carry):
            p, v = carry
            kk = jax.random.fold_in(k, step)
            idx = jax.random.randint(kk, (cfg.local_batch,), 0, count_i)
            g = grad_fn(p, jnp.take(feat_i, idx, 0), jnp.take(lab_i, idx, 0))
            if momentum:
                v = jax.tree.map(lambda vv, gg: cfg.momentum * vv + gg, v, g)
                upd = v
            else:
                upd = g
            p = jax.tree.map(lambda pp, uu: pp - lr * uu, p, upd)
            return p, v

        v0 = tree_zeros_like(params_v0)
        return jax.lax.fori_loop(0, cfg.local_steps, one, (params_v0, v0))

    def body(state, inp, ef):
        lr = cfg.lr_a if momentum else _lr(cfg, state.t)
        keys = jax.random.split(inp.key, data.num_clients)

        def client_fn(f_, l_, c_, k_):
            p_local, _ = local(state.params, f_, l_, c_, k_, lr)
            delta = jax.tree.map(lambda u, p: u - p, p_local, state.params)
            return delta, jnp.zeros((), jnp.float32)

        ckeys = (jax.random.split(jax.random.fold_in(inp.key, 0xC0DEC),
                                  data.num_clients)
                 if codec is not None else None)
        s = topo.weighted_sum(client_fn,
                              (data.features, data.labels, data.counts, keys),
                              w, codec=codec, ef=ef, codec_keys=ckeys)
        params = jax.tree.map(lambda p, d: (p + d).astype(p.dtype),
                              state.params, s.weighted)
        new = SGDState(params=params, t=state.t + 1)
        return new, s.ef, {"upload_bytes": up_bytes}

    state = _wrap_codec_state(
        SGDState(params=params0, t=jnp.ones((), jnp.int32)), codec,
        lambda: comm_ef.ef_init_stacked(data.num_clients, dim))
    return _run(with_comm_carry(codec, body), state, key, rounds, eval_fn,
                eval_every, topology=topology)


def feature_sgd(head_loss_from_h, client_h, params0, data: FeatureFedData,
                cfg: SGDConfig, rounds: int, key, eval_fn=None,
                eval_every: int = 10, momentum: bool = False,
                codec=None) -> RunResult:
    """One global (momentum-)SGD step per round via the Alg-3 info collection
    (codec compresses the same q-uploads as Algorithm 3)."""
    def body(state, inp, ef):
        if momentum:
            params, v, t = state.params, state.v, state.t
        else:
            params, t = state.params, state.t
        grad_est, _, up = fed.feature_round(
            params, data, inp.key, cfg.local_batch, head_loss_from_h,
            client_h, codec=codec, ef=ef)
        grad_est = jax.tree.map(
            lambda g, p: g + 2 * cfg.l2_lambda * p, grad_est, params)
        lr = cfg.lr_a if momentum else _lr(cfg, t)
        if momentum:
            v = jax.tree.map(lambda vv, gg: cfg.momentum * vv + gg, v, grad_est)
            params = jax.tree.map(lambda p, u: p - lr * u, params, v)
            new = SGDmState(params=params, v=v, t=t + 1)
        else:
            params = jax.tree.map(lambda p, g: p - lr * g, params, grad_est)
            new = SGDState(params=params, t=t + 1)
        metrics = {"upload_bytes": _feature_upload_bytes(
            up, grad_est, data, cfg.local_batch)}
        return new, up["ef"], metrics

    if momentum:
        state = SGDmState(params=params0, v=tree_zeros_like(params0),
                          t=jnp.ones((), jnp.int32))
    else:
        state = SGDState(params=params0, t=jnp.ones((), jnp.int32))
    state = _wrap_codec_state(
        state, codec, lambda: _feature_ef0(params0, data.num_clients))
    return _run(with_comm_carry(codec, body), state, key, rounds, eval_fn,
                eval_every)

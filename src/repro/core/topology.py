"""Topology layer: WHERE the paper's clients execute (DESIGN.md §11).

The sample-based protocol (Algorithms 1/2, the SGD baselines, the
local-update extension) has one structural invariant: every round is

    per-client compute  →  per-client upload (optionally DP clip+noised,
    then codec+EF compressed, at the client boundary)  →  server weighted
    sum  Σ_i w_i û_i

with w_i = N_i/(B_i·N) (eq. 9's aggregation, generalized to ragged clients
and Horvitz-Thompson participation reweighting). This module abstracts that
shape behind one contract, ``weighted_sum``, with two realizations.

``weighted_sum`` is leading-axis-generic: the dense engine passes
(I, ...)-leading args (every client in the population), the O(S) cohort
engine (``fed.cohort_round``, DESIGN.md §14) passes the (S, ...)-leading
cohort slice — client execution, codec encode, and the weighted psum then
run over S participants only, and a `ShardedTopology` shards the COHORT
(S must divide the shard count; population size never constrains the mesh).
The two realizations:

* :class:`LocalTopology` — all I clients on one device, `jax.vmap` over the
  client axis, `jnp.tensordot` for the server sum. Bit-for-bit the engine
  the repo has always run; kept as the equivalence reference.
* :class:`ShardedTopology` — clients distributed over the mesh's
  ("pod","data") axes via `jax.experimental.shard_map`: each device vmaps
  its I/D resident clients, applies the codec encode + error-feedback
  residual update *per shard before any collective* (compression happens at
  the client boundary, exactly as in the simulation), reduces its local
  Σ w_i û_i partial, and the eq.-(9) server aggregation is realized as a
  weighted `lax.psum` over the client axes. Per-client state (EF residuals,
  uploads) never leaves its shard; only the B-summed, weighted q-statistics
  cross devices — the mesh realization of the paper's model-aggregation
  privacy argument.

Both topologies compose with the scan-compiled round driver
(`core/rounds.py`): the shard_map sits inside the scanned step, so a K-round
epoch is still ONE dispatch, now spanning D devices, with the per-client EF
residuals riding the scan carry sharded over clients
(`ShardedTopology.place_state` pre-places them).

Equivalence: sharded == local up to float reassociation (per-device partial
sums + psum vs one tensordot); `tests/test_topology.py` pins the trajectory
at atol 1e-5 with codec=int8 + error feedback + partial participation all
enabled at once.

The feature-based protocol (Algorithms 3/4, vertical FL, DESIGN.md §12) has
a different structural invariant — the clients hold feature *blocks*, not
sample shards, and the round is

    client i computes h_i(ω_i, x_i)  →  h-exchange (every client sees all
    h_j)  →  head gradient q_{f,0,0} from Σ h  →  per-client block gradients
    q_{f,0,i} via the chain rule through the client's OWN h_i

— realized here by the second contract, ``feature_sum``. The sharded
realization places feature clients on the mesh's "model" axis
(`launch.mesh.make_feature_mesh`) and implements the paper's step-4
h-broadcast as a tiled `lax.all_gather`: every shard reassembles the full
(I, B, J) h in canonical client order, so Σ_i h_i — and hence the head
gradient, the backpropagated dl/dh, the block gradients, and the codec wire
formats — is bit-identical to the local vmap reference, not merely close.
The head computation is replicated (every client CAN compute it from the
broadcast h's; a deployment would let the fastest one), the block gradients
never leave their shard, and the codec + error-feedback roundtrip runs per
shard exactly like the sample-based path.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.comm import codecs as comm_codecs
from repro.comm import error_feedback as comm_ef
from repro.core import privacy as privacy_lib
from repro.obs import trace as obs_trace


class ClientSums(NamedTuple):
    """Everything a round produces at and across the client boundary."""
    weighted: object          # Σ_i w_i û_i — server aggregate (pytree)
    value: jnp.ndarray        # Σ_i w_i val_i — scalar aggregate
    uploads: object           # per-client û_i, stacked (I, ...) pytree
    values: jnp.ndarray       # per-client val_i, (I,)
    encoded: object           # codec wire format per client (None if dense)
    ef: object                # updated EF residuals (I, P) (None if dense)
    dp: object = None         # clip/noise stats per client (None if no DP)


def _compress_stacked(codec, uploads, ef, codec_keys, active):
    """Shared client-boundary compression: flatten each client's upload to
    one (P,) vector, run the codec through an error-feedback roundtrip, and
    hand back the decoded uploads the server will aggregate. Identical code
    runs under local vmap and inside each shard_map shard — the client
    boundary does not move with the topology."""
    uf, unflatten = comm_codecs.flatten_stacked(uploads)
    if ef is None:
        ef = jnp.zeros_like(uf)
    if active is None:
        active = jnp.ones((uf.shape[0],), jnp.float32)
    enc, u_hat, new_ef = jax.vmap(
        lambda x, r, k, a: comm_ef.ef_roundtrip(codec, x, r, k, a)
    )(uf, ef, codec_keys, active)
    return enc, unflatten(u_hat), new_ef


def _privatize_stacked(dp, uploads, dp_keys, dp_scale):
    """Shared client-boundary DP stage (DESIGN.md §15): flatten each
    client's upload to one (P,) vector and clip+noise it at mean scale
    (``dp_scale`` = 1/B_i converts the B_i-sum; None = already means).
    Runs BEFORE :func:`_compress_stacked`, so the codec wire format, the
    bytes accounting, and the EF residual all see the privatized upload.
    Identical code under local vmap and inside each shard_map shard — the
    sharded psum aggregates already-noised contributions."""
    uf, unflatten = comm_codecs.flatten_stacked(uploads)
    priv, stats = privacy_lib.clip_and_noise(uf, dp_keys, dp, dp_scale)
    return unflatten(priv), stats


class FeatureSums(NamedTuple):
    """Everything an Algorithm-3/4 vertical round produces at and across the
    client boundary (the feature-based analog of :class:`ClientSums`)."""
    h: object                 # per-client h_i, (I, B, J) — the h-exchange
    h_sum: jnp.ndarray        # Σ_i h_i, (B, J), replicated
    value: jnp.ndarray        # head batch value Σ_n f (scalar)
    q_head: object            # q_{f,0,0} head upload (decoded if codec)
    q_blocks: object          # q_{f,0,i} block uploads, (I, ...) pytree
    encoded: object           # {"q_head","q_blocks"} wire formats (None dense)
    ef: object                # {"w0": (P0,), "blocks": (I, Pb)} residuals
    dp: object = None         # clip/noise stats per stream (None if no DP)


def _compress_feature(codec, q_head, q_blocks, ef, head_key, block_keys):
    """Client-boundary compression for the feature-based uploads: ONE head
    stream (q_{f,0,0}, uploaded by the client that computed it) plus one
    stream per client block (q_{f,0,i}), each through its own error-feedback
    roundtrip. Identical code runs under local vmap and inside each
    shard_map shard; under the sharded topology the head roundtrip is
    replicated compute on bit-identical inputs (same key), so its wire
    format agrees across every shard."""
    f0, unf0 = comm_codecs.flatten_tree(q_head)
    fb, unfb = comm_codecs.flatten_stacked(q_blocks)
    if ef is None:
        ef = {"w0": jnp.zeros_like(f0), "blocks": jnp.zeros_like(fb)}
    enc0, h0, r0 = comm_ef.ef_roundtrip(codec, f0, ef["w0"], head_key)
    encb, hb, rb = jax.vmap(
        lambda x, r, k: comm_ef.ef_roundtrip(codec, x, r, k)
    )(fb, ef["blocks"], block_keys)
    return ({"q_head": enc0, "q_blocks": encb}, unf0(h0), unfb(hb),
            {"w0": r0, "blocks": rb})


def _privatize_feature(dp, q_head, q_blocks, dp_head_key, dp_block_keys,
                       dp_scale):
    """Client-boundary DP stage for the feature-based uploads: the ONE head
    stream (q_{f,0,0}) plus one stream per client block (q_{f,0,i}), each
    clipped and noised at mean scale (``dp_scale`` = 1/B — the uploads are
    batch sums) BEFORE :func:`_compress_feature`. Under the sharded
    topology the head stage is replicated compute on bit-identical inputs
    (same key → same noise), so every shard agrees; the step-4 h-exchange
    itself is NOT privatized (it feeds gradients, not the released
    aggregate — documented in DESIGN.md §15)."""
    f0, unf0 = comm_codecs.flatten_tree(q_head)
    p0, st0 = privacy_lib.clip_and_noise(
        f0[None], dp_head_key[None], dp, jnp.full((1,), dp_scale))
    fb, unfb = comm_codecs.flatten_stacked(q_blocks)
    pb, stb = privacy_lib.clip_and_noise(
        fb, dp_block_keys, dp, jnp.full((fb.shape[0],), dp_scale))
    stats = {"head_clipped": st0["clipped"][0],
             "head_noise_sq": st0["noise_sq"][0],
             "blocks_clipped": stb["clipped"],
             "blocks_noise_sq": stb["noise_sq"]}
    return unf0(p0[0]), unfb(pb), stats


def _weighted(weights, uploads, values):
    weighted = jax.tree.map(
        lambda u: jnp.tensordot(weights, u.astype(jnp.float32), axes=1),
        uploads)
    return weighted, jnp.dot(weights, values)


class LocalTopology:
    """All clients on one device: vmap over the client axis (the reference
    engine — every sharded result is pinned against this one)."""

    name = "local"
    num_shards = 1

    def weighted_sum(self, client_fn: Callable, args, weights, *,
                     codec=None, ef=None, codec_keys=None, active=None,
                     dp=None, dp_keys=None, dp_scale=None) -> ClientSums:
        """client_fn(*per_client_args) -> (upload pytree, val scalar); args
        are (I, ...)-leading arrays; returns all of :class:`ClientSums`.
        With ``dp=`` (a privacy.DPConfig) each client's upload is
        clipped+noised at the client boundary BEFORE any codec encode."""
        with obs_trace.phase("client-compute"):
            uploads, values = jax.vmap(client_fn)(*args)
        enc = new_ef = dp_stats = None
        if dp is not None:
            with obs_trace.phase("dp-privatize"):
                uploads, dp_stats = _privatize_stacked(dp, uploads, dp_keys,
                                                       dp_scale)
        if codec is not None:
            with obs_trace.phase("codec-encode"):
                enc, uploads, new_ef = _compress_stacked(codec, uploads, ef,
                                                         codec_keys, active)
        with obs_trace.phase("aggregate"):
            weighted, value = _weighted(weights, uploads, values)
        return ClientSums(weighted=weighted, value=value, uploads=uploads,
                          values=values, encoded=enc, ef=new_ef, dp=dp_stats)

    def feature_sum(self, h_fn: Callable, head_fn: Callable,
                    block_grad_fn: Callable, blocks, zb, *,
                    codec=None, ef=None, head_key=None, block_keys=None,
                    dp=None, dp_head_key=None, dp_block_keys=None,
                    dp_scale=1.0) -> FeatureSums:
        """Alg-3/4 information flow, all clients on one device.

        h_fn(block_i, zb_i) -> (B, J) per-client h; head_fn(h_sum) ->
        (value, q_head, dl_dh) closes over the head params and labels;
        block_grad_fn(block_i, zb_i, dl_dh) -> q_{f,0,i}. blocks/zb are
        (I, ...)-leading. With ``dp=`` the head + block q-uploads are
        clipped+noised before any codec encode (the h-exchange stays in
        the clear — DESIGN.md §15). This vmap path is the bit-level
        reference every sharded result is pinned against."""
        with obs_trace.phase("client-compute"):
            h = jax.vmap(h_fn)(blocks, zb)                   # (I, B, J)
        with obs_trace.phase("aggregate"):
            h_sum = jnp.sum(h, axis=0)
        with obs_trace.phase("head-compute"):
            value, q_head, dl_dh = head_fn(h_sum)
        with obs_trace.phase("client-compute"):
            q_blocks = jax.vmap(block_grad_fn, in_axes=(0, 0, None))(
                blocks, zb, dl_dh)
        enc = new_ef = dp_stats = None
        if dp is not None:
            with obs_trace.phase("dp-privatize"):
                q_head, q_blocks, dp_stats = _privatize_feature(
                    dp, q_head, q_blocks, dp_head_key, dp_block_keys,
                    dp_scale)
        if codec is not None:
            with obs_trace.phase("codec-encode"):
                enc, q_head, q_blocks, new_ef = _compress_feature(
                    codec, q_head, q_blocks, ef, head_key, block_keys)
        return FeatureSums(h=h, h_sum=h_sum, value=value, q_head=q_head,
                           q_blocks=q_blocks, encoded=enc, ef=new_ef,
                           dp=dp_stats)

    def place_state(self, state):
        """No placement to do on a single device."""
        return state

    def place_feature_state(self, state):
        """No placement to do on a single device."""
        return state


class ShardedTopology:
    """Clients distributed over the mesh's client axes via shard_map; the
    eq.-(9) server aggregation is a weighted `lax.psum`.

    mesh: a `jax.sharding.Mesh` whose client axes (default: the ("pod",
    "data") axes present, else all axes) carry the clients. The client count
    I must be divisible by the product of the client-axis sizes D; each
    device executes I/D clients.
    """

    name = "sharded"

    def __init__(self, mesh, axes: Optional[Sequence[str]] = None):
        self.mesh = mesh
        if axes is None:
            axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            axes = axes or tuple(mesh.axis_names)
        self.axes = tuple(axes)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.num_shards = math.prod(sizes[a] for a in self.axes)

    def _check_divisible(self, num_clients: int):
        if num_clients % self.num_shards:
            raise ValueError(
                f"num_clients={num_clients} must be divisible by the "
                f"{self.num_shards} client shards of mesh axes {self.axes} "
                "(pad the client set or pick a smaller mesh)")

    def client_sharding(self):
        """NamedSharding placing a leading client axis over this topology's
        mesh axes (used to pre-place datasets and EF carries)."""
        return jax.sharding.NamedSharding(self.mesh, P(self.axes))

    def place_state(self, state):
        """Pre-place the per-client EF residuals of a `CommCarry` scan state
        over the client axes, so the carry starts (and stays) sharded across
        the K scanned rounds instead of being resharded on first use."""
        if not isinstance(state, comm_ef.CommCarry) or state.ef is None:
            return state
        sh = self.client_sharding()

        def put(x):
            # a keyed EFStore (cohort engine, DESIGN.md §14) is indexed by
            # POPULATION id — what shards is the (S, P) cohort slice inside
            # weighted_sum, so the backing stays replicated/default-placed
            if isinstance(x, comm_ef.EFStore):
                return x
            if (hasattr(x, "ndim") and x.ndim >= 1
                    and x.shape[0] % self.num_shards == 0):
                return jax.device_put(x, sh)
            return x

        return state._replace(
            ef=jax.tree.map(put, state.ef,
                            is_leaf=lambda v: isinstance(v, comm_ef.EFStore)))

    def weighted_sum(self, client_fn: Callable, args, weights, *,
                     codec=None, ef=None, codec_keys=None, active=None,
                     dp=None, dp_keys=None, dp_scale=None) -> ClientSums:
        """Same contract as :meth:`LocalTopology.weighted_sum`, executed
        shard-locally with the server sum as a weighted psum. The DP
        clip+noise stage, codec encode, and EF update all run per shard
        BEFORE the collective: each shard noises its own resident clients'
        uploads, so the psum aggregates already-noised contributions and
        what crosses the device boundary is the already-weighted decoded
        privatized aggregate — the wire format / residuals stay
        client-resident."""
        self._check_divisible(weights.shape[0])
        axes = self.axes
        spec = P(axes)
        has_codec = codec is not None
        has_dp = dp is not None

        def body(args_l, weights_l, ef_l, keys_l, act_l, dpk_l, dps_l):
            with obs_trace.phase("client-compute"):
                uploads, values = jax.vmap(client_fn)(*args_l)
            enc = new_ef = dp_stats = None
            if has_dp:
                with obs_trace.phase("dp-privatize"):
                    uploads, dp_stats = _privatize_stacked(dp, uploads,
                                                           dpk_l, dps_l)
            if has_codec:
                with obs_trace.phase("codec-encode"):
                    enc, uploads, new_ef = _compress_stacked(
                        codec, uploads, ef_l, keys_l, act_l)
            with obs_trace.phase("aggregate"):
                partial, val_partial = _weighted(weights_l, uploads, values)
            with obs_trace.phase("collective"):
                weighted = jax.lax.psum(partial, axes)
                value = jax.lax.psum(val_partial, axes)
            return weighted, value, uploads, values, enc, new_ef, dp_stats

        sharded = shard_map(
            body, mesh=self.mesh,
            in_specs=(spec, spec, spec, spec, spec, spec, spec),
            out_specs=(P(), P(), spec, spec, spec, spec, spec),
            check_rep=False)
        weighted, value, uploads, values, enc, new_ef, dp_stats = sharded(
            tuple(args), weights, ef, codec_keys, active, dp_keys, dp_scale)
        return ClientSums(weighted=weighted, value=value, uploads=uploads,
                          values=values, encoded=enc, ef=new_ef, dp=dp_stats)

    def place_feature_state(self, state):
        """Pre-place a feature-based `CommCarry`'s EF residual dict: the
        per-client block residuals (I, Pb) shard over the client axes, the
        single head stream (P0,) stays replicated — matching feature_sum's
        out_specs so the scan carry never reshards."""
        if (not isinstance(state, comm_ef.CommCarry)
                or not isinstance(state.ef, dict)):
            return state
        sh = self.client_sharding()
        rep = jax.sharding.NamedSharding(self.mesh, P())
        ef = {k: jax.device_put(v, sh if k == "blocks" else rep)
              for k, v in state.ef.items()}
        return state._replace(ef=ef)

    def feature_sum(self, h_fn: Callable, head_fn: Callable,
                    block_grad_fn: Callable, blocks, zb, *,
                    codec=None, ef=None, head_key=None, block_keys=None,
                    dp=None, dp_head_key=None, dp_block_keys=None,
                    dp_scale=1.0) -> FeatureSums:
        """Same contract as :meth:`LocalTopology.feature_sum`, with each
        shard running its I/D resident feature clients and the paper's
        step-4 h-broadcast realized as a tiled `lax.all_gather` over the
        client axes: every shard reassembles the FULL (I, B, J) h in
        canonical client order, so Σ_i h_i — and everything downstream of
        it (head gradient, dl/dh, block gradients, codec wire formats) —
        is bit-identical to the local reference. The head computation, its
        DP clip+noise, and its codec roundtrip are replicated per shard
        (same inputs, same keys → same bits); block gradients, their noise
        draws, and their EF residuals never leave their shard."""
        num_clients = jax.tree.leaves(blocks)[0].shape[0]
        self._check_divisible(num_clients)
        axes = self.axes
        spec = P(axes)
        has_codec = codec is not None
        has_dp = dp is not None
        ef_spec = ({"w0": P(), "blocks": spec}
                   if has_codec and ef is not None else P())
        keys_spec = spec if block_keys is not None else P()
        enc_spec = {"q_head": P(), "q_blocks": spec} if has_codec else P()
        ef_out_spec = {"w0": P(), "blocks": spec} if has_codec else P()
        dp_keys_spec = spec if dp_block_keys is not None else P()
        dp_out_spec = ({"head_clipped": P(), "head_noise_sq": P(),
                        "blocks_clipped": spec, "blocks_noise_sq": spec}
                       if has_dp else P())

        def body(blocks_l, zb_l, ef_l, bkeys_l, hkey, dpbk_l, dphk):
            with obs_trace.phase("client-compute"):
                h_l = jax.vmap(h_fn)(blocks_l, zb_l)         # (I/D, B, J)
            with obs_trace.phase("collective"):
                h_all = jax.lax.all_gather(h_l, axes, axis=0, tiled=True)
            with obs_trace.phase("aggregate"):
                h_sum = jnp.sum(h_all, axis=0)
            with obs_trace.phase("head-compute"):
                value, q_head, dl_dh = head_fn(h_sum)
            with obs_trace.phase("client-compute"):
                q_blocks = jax.vmap(block_grad_fn, in_axes=(0, 0, None))(
                    blocks_l, zb_l, dl_dh)
            enc = new_ef = dp_stats = None
            if has_dp:
                with obs_trace.phase("dp-privatize"):
                    q_head, q_blocks, dp_stats = _privatize_feature(
                        dp, q_head, q_blocks, dphk, dpbk_l, dp_scale)
            if has_codec:
                with obs_trace.phase("codec-encode"):
                    enc, q_head, q_blocks, new_ef = _compress_feature(
                        codec, q_head, q_blocks, ef_l, hkey, bkeys_l)
            return h_l, h_sum, value, q_head, q_blocks, enc, new_ef, dp_stats

        sharded = shard_map(
            body, mesh=self.mesh,
            in_specs=(spec, spec, ef_spec, keys_spec, P(), dp_keys_spec, P()),
            out_specs=(spec, P(), P(), P(), spec, enc_spec, ef_out_spec,
                       dp_out_spec),
            check_rep=False)
        h, h_sum, value, q_head, q_blocks, enc, new_ef, dp_stats = sharded(
            blocks, zb, ef, block_keys, head_key, dp_block_keys, dp_head_key)
        return FeatureSums(h=h, h_sum=h_sum, value=value, q_head=q_head,
                           q_blocks=q_blocks, encoded=enc, ef=new_ef,
                           dp=dp_stats)


LOCAL = LocalTopology()


def make_topology(name: str, mesh=None, axes=None):
    """CLI-name -> topology. "local" ignores mesh; "sharded" uses the given
    mesh or builds a 1-D client mesh over all host devices
    (`launch.mesh.make_client_mesh`)."""
    if name == "local":
        return LOCAL
    if name == "sharded":
        if mesh is None:
            from repro.launch.mesh import make_client_mesh
            mesh = make_client_mesh()
        return ShardedTopology(mesh, axes=axes)
    raise ValueError(f"unknown topology {name!r} (choose local|sharded)")


def sharded_for(num_clients: int) -> ShardedTopology:
    """ShardedTopology over the MOST host devices that divide the client
    count — the one divisibility-fitting policy shared by the example
    sweeps and the adaptive tests (a 1-device fit still runs the
    shard_map + psum path, so callers need no special-casing)."""
    from repro.launch.mesh import make_client_mesh
    d = jax.device_count()
    while num_clients % d:
        d -= 1
    return ShardedTopology(make_client_mesh(d))


def feature_sharded_for(num_clients: int) -> ShardedTopology:
    """Feature-based analog of :func:`sharded_for`: the same best-divisor
    device fit, but over a "model"-axis mesh (DESIGN.md §2/§12 — feature
    clients ARE model shards; a 1-device fit still runs the shard_map +
    all_gather path)."""
    from repro.launch.mesh import make_feature_mesh
    d = jax.device_count()
    while num_clients % d:
        d -= 1
    return ShardedTopology(make_feature_mesh(d))

"""Topology layer: WHERE the paper's clients execute (DESIGN.md §11).

The sample-based protocol (Algorithms 1/2, the SGD baselines, the
local-update extension) has one structural invariant: every round is

    per-client compute  →  per-client upload (optionally codec+EF compressed
    at the client boundary)  →  server weighted sum  Σ_i w_i û_i

with w_i = N_i/(B_i·N) (eq. 9's aggregation, generalized to ragged clients
and Horvitz-Thompson participation reweighting). This module abstracts that
shape behind one contract, ``weighted_sum``, with two realizations:

* :class:`LocalTopology` — all I clients on one device, `jax.vmap` over the
  client axis, `jnp.tensordot` for the server sum. Bit-for-bit the engine
  the repo has always run; kept as the equivalence reference.
* :class:`ShardedTopology` — clients distributed over the mesh's
  ("pod","data") axes via `jax.experimental.shard_map`: each device vmaps
  its I/D resident clients, applies the codec encode + error-feedback
  residual update *per shard before any collective* (compression happens at
  the client boundary, exactly as in the simulation), reduces its local
  Σ w_i û_i partial, and the eq.-(9) server aggregation is realized as a
  weighted `lax.psum` over the client axes. Per-client state (EF residuals,
  uploads) never leaves its shard; only the B-summed, weighted q-statistics
  cross devices — the mesh realization of the paper's model-aggregation
  privacy argument.

Both topologies compose with the scan-compiled round driver
(`core/rounds.py`): the shard_map sits inside the scanned step, so a K-round
epoch is still ONE dispatch, now spanning D devices, with the per-client EF
residuals riding the scan carry sharded over clients
(`ShardedTopology.place_state` pre-places them).

Equivalence: sharded == local up to float reassociation (per-device partial
sums + psum vs one tensordot); `tests/test_topology.py` pins the trajectory
at atol 1e-5 with codec=int8 + error feedback + partial participation all
enabled at once.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.comm import codecs as comm_codecs
from repro.comm import error_feedback as comm_ef


class ClientSums(NamedTuple):
    """Everything a round produces at and across the client boundary."""
    weighted: object          # Σ_i w_i û_i — server aggregate (pytree)
    value: jnp.ndarray        # Σ_i w_i val_i — scalar aggregate
    uploads: object           # per-client û_i, stacked (I, ...) pytree
    values: jnp.ndarray       # per-client val_i, (I,)
    encoded: object           # codec wire format per client (None if dense)
    ef: object                # updated EF residuals (I, P) (None if dense)


def _compress_stacked(codec, uploads, ef, codec_keys, active):
    """Shared client-boundary compression: flatten each client's upload to
    one (P,) vector, run the codec through an error-feedback roundtrip, and
    hand back the decoded uploads the server will aggregate. Identical code
    runs under local vmap and inside each shard_map shard — the client
    boundary does not move with the topology."""
    uf, unflatten = comm_codecs.flatten_stacked(uploads)
    if ef is None:
        ef = jnp.zeros_like(uf)
    if active is None:
        active = jnp.ones((uf.shape[0],), jnp.float32)
    enc, u_hat, new_ef = jax.vmap(
        lambda x, r, k, a: comm_ef.ef_roundtrip(codec, x, r, k, a)
    )(uf, ef, codec_keys, active)
    return enc, unflatten(u_hat), new_ef


def _weighted(weights, uploads, values):
    weighted = jax.tree.map(
        lambda u: jnp.tensordot(weights, u.astype(jnp.float32), axes=1),
        uploads)
    return weighted, jnp.dot(weights, values)


class LocalTopology:
    """All clients on one device: vmap over the client axis (the reference
    engine — every sharded result is pinned against this one)."""

    name = "local"
    num_shards = 1

    def weighted_sum(self, client_fn: Callable, args, weights, *,
                     codec=None, ef=None, codec_keys=None,
                     active=None) -> ClientSums:
        """client_fn(*per_client_args) -> (upload pytree, val scalar); args
        are (I, ...)-leading arrays; returns all of :class:`ClientSums`."""
        uploads, values = jax.vmap(client_fn)(*args)
        enc = new_ef = None
        if codec is not None:
            enc, uploads, new_ef = _compress_stacked(codec, uploads, ef,
                                                     codec_keys, active)
        weighted, value = _weighted(weights, uploads, values)
        return ClientSums(weighted=weighted, value=value, uploads=uploads,
                          values=values, encoded=enc, ef=new_ef)

    def place_state(self, state):
        """No placement to do on a single device."""
        return state


class ShardedTopology:
    """Clients distributed over the mesh's client axes via shard_map; the
    eq.-(9) server aggregation is a weighted `lax.psum`.

    mesh: a `jax.sharding.Mesh` whose client axes (default: the ("pod",
    "data") axes present, else all axes) carry the clients. The client count
    I must be divisible by the product of the client-axis sizes D; each
    device executes I/D clients.
    """

    name = "sharded"

    def __init__(self, mesh, axes: Optional[Sequence[str]] = None):
        self.mesh = mesh
        if axes is None:
            axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            axes = axes or tuple(mesh.axis_names)
        self.axes = tuple(axes)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.num_shards = math.prod(sizes[a] for a in self.axes)

    def _check_divisible(self, num_clients: int):
        if num_clients % self.num_shards:
            raise ValueError(
                f"num_clients={num_clients} must be divisible by the "
                f"{self.num_shards} client shards of mesh axes {self.axes} "
                "(pad the client set or pick a smaller mesh)")

    def client_sharding(self):
        """NamedSharding placing a leading client axis over this topology's
        mesh axes (used to pre-place datasets and EF carries)."""
        return jax.sharding.NamedSharding(self.mesh, P(self.axes))

    def place_state(self, state):
        """Pre-place the per-client EF residuals of a `CommCarry` scan state
        over the client axes, so the carry starts (and stays) sharded across
        the K scanned rounds instead of being resharded on first use."""
        if not isinstance(state, comm_ef.CommCarry) or state.ef is None:
            return state
        sh = self.client_sharding()

        def put(x):
            if (hasattr(x, "ndim") and x.ndim >= 1
                    and x.shape[0] % self.num_shards == 0):
                return jax.device_put(x, sh)
            return x

        return state._replace(ef=jax.tree.map(put, state.ef))

    def weighted_sum(self, client_fn: Callable, args, weights, *,
                     codec=None, ef=None, codec_keys=None,
                     active=None) -> ClientSums:
        """Same contract as :meth:`LocalTopology.weighted_sum`, executed
        shard-locally with the server sum as a weighted psum. Codec encode +
        EF update run per shard BEFORE the collective: what crosses the
        device boundary is the already-weighted decoded aggregate, and the
        wire format / residuals stay client-resident."""
        self._check_divisible(weights.shape[0])
        axes = self.axes
        spec = P(axes)
        has_codec = codec is not None

        def body(args_l, weights_l, ef_l, keys_l, act_l):
            uploads, values = jax.vmap(client_fn)(*args_l)
            enc = new_ef = None
            if has_codec:
                enc, uploads, new_ef = _compress_stacked(
                    codec, uploads, ef_l, keys_l, act_l)
            partial, val_partial = _weighted(weights_l, uploads, values)
            weighted = jax.lax.psum(partial, axes)
            value = jax.lax.psum(val_partial, axes)
            return weighted, value, uploads, values, enc, new_ef

        sharded = shard_map(
            body, mesh=self.mesh,
            in_specs=(spec, spec, spec, spec, spec),
            out_specs=(P(), P(), spec, spec, spec, spec),
            check_rep=False)
        weighted, value, uploads, values, enc, new_ef = sharded(
            tuple(args), weights, ef, codec_keys, active)
        return ClientSums(weighted=weighted, value=value, uploads=uploads,
                          values=values, encoded=enc, ef=new_ef)


LOCAL = LocalTopology()


def make_topology(name: str, mesh=None, axes=None):
    """CLI-name -> topology. "local" ignores mesh; "sharded" uses the given
    mesh or builds a 1-D client mesh over all host devices
    (`launch.mesh.make_client_mesh`)."""
    if name == "local":
        return LOCAL
    if name == "sharded":
        if mesh is None:
            from repro.launch.mesh import make_client_mesh
            mesh = make_client_mesh()
        return ShardedTopology(mesh, axes=axes)
    raise ValueError(f"unknown topology {name!r} (choose local|sharded)")


def sharded_for(num_clients: int) -> ShardedTopology:
    """ShardedTopology over the MOST host devices that divide the client
    count — the one divisibility-fitting policy shared by the example
    sweeps and the adaptive tests (a 1-device fit still runs the
    shard_map + psum path, so callers need no special-casing)."""
    from repro.launch.mesh import make_client_mesh
    d = jax.device_count()
    while num_clients % d:
        d -= 1
    return ShardedTopology(make_client_mesh(d))

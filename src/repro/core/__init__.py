"""The paper's primary contribution: mini-batch SSCA federated optimization.

  schedules  — stepsize rules (4)/(6)
  surrogate  — recursive quadratic surrogates (3)/(8)-(9)/(14)-(16)/(25)
  solvers    — closed-form/lax solvers for Problems 2/5/7/10 (incl. Lemma 1)
  optimizer  — SSCA as a composable (state, grad) -> state optimizer
  fed        — client containers, per-round uploads, aggregation, comm loads
  rounds     — scan-compiled multi-round driver (one dispatch per K rounds)
  topology   — WHERE clients execute: local vmap vs device-sharded shard_map
  algorithms — faithful Algorithm 1-4 drivers
  baselines  — FedSGD / FedAvg / PR-SGD / SGD-m comparison algorithms
  tree       — shared pytree arithmetic helpers (axpy/dot/l2sq/zeros)
"""
from repro.core import (algorithms, baselines, fed, optimizer, rounds,  # noqa: F401
                        schedules, solvers, surrogate, topology, tree)

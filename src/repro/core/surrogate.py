"""Recursive convex surrogates (paper eqs. (3), (8)-(9), (14)-(15), (16), (25)).

With the paper's quadratic surrogate choice
    f̄(ω; ω', x) = f(ω'; x) + ∇f(ω'; x)ᵀ(ω-ω') + τ‖ω-ω'‖²          (7)/(15)
the running surrogate  F̄^t(ω) = (1-ρ^t)F̄^(t-1)(ω) + ρ^t · [batch avg of f̄]
collapses to the canonical quadratic form

    F̄^t(ω) = d^t + (g^t)ᵀ ω + τ‖ω‖²

whose state is one scalar d^t and one param-shaped buffer g^t with recursions

    g^t = (1-ρ^t) g^(t-1) + ρ^t (ĝ^t - 2τ ω^t)                      (9)
    d^t = (1-ρ^t) d^(t-1) + ρ^t (F̂^t - (ĝ^t)ᵀω^t + τ‖ω^t‖²)        (42)

(d is only needed for constraints; the objective's d never enters argmin).
ĝ^t / F̂^t are the mini-batch gradient / value estimates aggregated over clients
with weights N_i/(BN) — in the distributed runtime that aggregation *is* the
data-axis all-reduce.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


# Pytree helpers moved to repro.core.tree (single home, DESIGN.md §3);
# re-exported here because this was their original address.
from repro.core.tree import (tree_axpy, tree_dot, tree_l2sq,  # noqa: F401
                             tree_zeros_like)


class QuadSurrogate(NamedTuple):
    """State of F̄^t(ω) = d + gᵀω + τ‖ω‖²."""
    d: jnp.ndarray      # scalar
    g: object           # pytree like params


def init_surrogate(params, dtype=jnp.float32) -> QuadSurrogate:
    return QuadSurrogate(d=jnp.zeros((), jnp.float32),
                         g=tree_zeros_like(params, dtype))


def update_surrogate(s: QuadSurrogate, rho_t, omega, grad_est, value_est,
                     tau: float, extra_linear: float = 0.0) -> QuadSurrogate:
    """One recursion step.

    extra_linear adds a term ``extra_linear * ω`` to the injected gradient —
    used to fold an exact-gradient regularizer (e.g. 2λω for λ‖ω‖², eq. (35)
    folded; see DESIGN.md) into the same buffer.
    """
    inj = jax.tree.map(
        lambda gr, w: gr.astype(jnp.float32) + (extra_linear - 2.0 * tau) * w.astype(jnp.float32),
        grad_est, omega)
    g = tree_axpy(1.0 - rho_t, s.g, rho_t, inj)
    dval = value_est - tree_dot(grad_est, omega) + tau * tree_l2sq(omega)
    d = (1.0 - rho_t) * s.d + rho_t * dval
    return QuadSurrogate(d=d, g=g)


def surrogate_value(s: QuadSurrogate, omega, tau: float):
    return s.d + tree_dot(s.g, omega) + tau * tree_l2sq(omega)


def surrogate_grad(s: QuadSurrogate, omega, tau: float):
    return jax.tree.map(lambda g, w: g + 2.0 * tau * w.astype(jnp.float32), s.g, omega)

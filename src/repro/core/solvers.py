"""Closed-form / jax.lax solvers for the convex approximate problems.

Problem 2/7 (unconstrained):  argmin_ω gᵀω + τ‖ω‖²  =  -g/(2τ)     (eqs. 10/24)

Problem 5/10 (constrained, exact-penalty with slacks):
    min_ω,s   F̄_0(ω) + c Σ_m s_m   s.t.  F̄_m(ω) <= s_m,  s_m >= 0
with F̄_0 = g_0ᵀω + τ_0‖ω‖² and F̄_m = d_m + g_mᵀω + τ_c‖ω‖².

Dual: ω(ν) = -(g_0 + Σ ν_m g_m) / (2(τ_0 + τ_c Σ ν_m)), ν ∈ [0, c]^M.
For M = 1 the root of φ(ν) = F̄_1(ω(ν)) is found by monotone bisection (φ is
decreasing, = h'(ν) by the envelope theorem); the paper's Lemma 1 closed form
(g_0 = 0, τ_0 = 1) is provided separately and tested against the bisection.
For M > 1 we run projected gradient ascent on the concave dual — all control
flow is jax.lax, everything operates on Gram-matrix scalars so the per-round
cost beyond the gradient all-reduce is O(M²) scalars.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.surrogate import QuadSurrogate
from repro.core.tree import tree_axpy, tree_dot, tree_l2sq


def solve_unconstrained(g, tau: float):
    """argmin gᵀω + τ‖ω‖²  (eq. (10)/(24)). g: pytree -> ω̄ pytree."""
    return jax.tree.map(lambda x: -x / (2.0 * tau), g)


class ConstrainedSolution(NamedTuple):
    omega_bar: object       # pytree
    nu: jnp.ndarray         # (M,) dual variables in [0, c]
    slack: jnp.ndarray      # (M,) optimal slack values


def _gram(g0, gs: Sequence):
    vecs = [g0] + list(gs)
    n = len(vecs)
    dots = jnp.stack([jnp.stack([tree_dot(vecs[i], vecs[j]) for j in range(n)])
                      for i in range(n)])
    return dots    # (1+M, 1+M)


def _phi_single(nu, a00, a01, a11, d1, tau0, tauc):
    """F̄_1(ω(ν)) for M=1, from Gram scalars."""
    t = tau0 + nu * tauc
    g1w = -(a01 + nu * a11) / (2.0 * t)
    wsq = (a00 + 2.0 * nu * a01 + nu * nu * a11) / (4.0 * t * t)
    return d1 + g1w + tauc * wsq


def solve_constrained_single(g0, tau0: float, cons: QuadSurrogate, tauc: float,
                             c: float, iters: int = 64) -> ConstrainedSolution:
    """M=1 solver by bisection on the monotone φ(ν) over [0, c]."""
    a = _gram(g0, [cons.g])
    a00, a01, a11 = a[0, 0], a[0, 1], a[1, 1]
    d1 = cons.d

    phi0 = _phi_single(0.0, a00, a01, a11, d1, tau0, tauc)
    phic = _phi_single(jnp.float32(c), a00, a01, a11, d1, tau0, tauc)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        pm = _phi_single(mid, a00, a01, a11, d1, tau0, tauc)
        lo = jnp.where(pm > 0, mid, lo)
        hi = jnp.where(pm > 0, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (jnp.float32(0), jnp.float32(c)))
    nu_root = 0.5 * (lo + hi)
    nu = jnp.where(phi0 <= 0, 0.0, jnp.where(phic > 0, c, nu_root))

    t = tau0 + nu * tauc
    omega = jax.tree.map(lambda x0, x1: -(x0 + nu * x1) / (2.0 * t), g0, cons.g)
    slack = jnp.maximum(_phi_single(nu, a00, a01, a11, d1, tau0, tauc), 0.0)
    return ConstrainedSolution(omega, nu[None], slack[None])


def lemma1_nu(b, d1, tau: float, c: float):
    """The paper's Lemma 1 closed form (objective ‖ω‖², g0 = 0, τ0 = 1).

    b = ‖g_1‖² (eq. 45);  d1 = C^t - U. Returns ν*.
    """
    disc = b - 4.0 * tau * d1               # = b + 4τ(U - C) with d1 = C - U
    safe = jnp.maximum(disc, 1e-30)
    nu_int = (jnp.sqrt(b / safe) - 1.0) / tau
    nu_clip = jnp.clip(nu_int, 0.0, c)
    return jnp.where(disc > 0, nu_clip, c)


def kkt_residuals(obj_grad, cons_grads: Sequence, cons_values, nu):
    """KKT residuals at a primal point ω with multipliers ν ∈ R^M_+ for
    min f0(ω) s.t. F_m(ω) <= 0:

      stationarity   ‖∇f0(ω) + Σ_m ν_m ∇F_m(ω)‖₂
      violation      max_m max(F_m(ω), 0)
      comp_slack     max_m |ν_m · F_m(ω)|

    The paper's Theorems 2/4 state convergence to KKT points — these are
    exactly the residuals that must vanish, and what
    benchmarks/feature_bench.py scores Algorithm 4 against its baselines
    on. obj_grad/cons_grads are pytrees; cons_values is (M,)-shaped (pass
    F_m − U_m for a budget constraint F_m <= U_m)."""
    cons_values = jnp.atleast_1d(jnp.asarray(cons_values, jnp.float32))
    nu = jnp.atleast_1d(jnp.asarray(nu, jnp.float32))
    lag = obj_grad
    for m, g in enumerate(cons_grads):
        lag = tree_axpy(1.0, lag, nu[m], g)
    return {"stationarity": jnp.sqrt(tree_l2sq(lag)),
            "violation": jnp.max(jnp.maximum(cons_values, 0.0)),
            "comp_slack": jnp.max(jnp.abs(nu * cons_values))}


def kkt_best_nu(obj_grad, cons_grad):
    """Stationarity-minimizing multiplier for a single constraint:
    argmin_{ν>=0} ‖∇f0 + ν∇F‖² = max(0, −⟨∇f0, ∇F⟩/‖∇F‖²). Used to score
    methods that do not maintain a dual iterate (e.g. the Frank-Wolfe
    baseline) on the same KKT yardstick as the dual-bearing ones."""
    denom = jnp.maximum(tree_l2sq(cons_grad), 1e-30)
    return jnp.maximum(0.0, -tree_dot(obj_grad, cons_grad) / denom)


def solve_constrained_multi(g0, tau0: float, cons: Sequence[QuadSurrogate],
                            tauc: float, c: float,
                            iters: int = 200) -> ConstrainedSolution:
    """General M: projected gradient ascent on the concave dual over [0,c]^M.

    ∂h/∂ν_m = F̄_m(ω(ν)) (envelope theorem) — evaluated from Gram scalars only.
    """
    m = len(cons)
    gs = [s.g for s in cons]
    a = _gram(g0, gs)                       # (1+M, 1+M)
    d = jnp.stack([s.d for s in cons])      # (M,)

    def phi(nu):                            # (M,) -> (M,) constraint values
        t = tau0 + tauc * jnp.sum(nu)
        coef = jnp.concatenate([jnp.ones((1,)), nu])          # (1+M,)
        gw = -(a @ coef) / (2.0 * t)                          # g_kᵀω for k=0..M
        wsq = coef @ a @ coef / (4.0 * t * t)
        return d + gw[1:] + tauc * wsq

    # Lipschitz-safe stepsize from Gram magnitude
    lr = 1.0 / (1e-8 + jnp.max(jnp.abs(a)) / (2.0 * tau0 * tau0) + tauc)

    def body(_, nu):
        return jnp.clip(nu + lr * phi(nu), 0.0, c)

    nu = jax.lax.fori_loop(0, iters, body, jnp.zeros((m,)))
    t = tau0 + tauc * jnp.sum(nu)

    def comb(x0, *xs):
        out = x0.astype(jnp.float32)
        for w, xm in zip(nu, xs):
            out = out + w * xm
        return -out / (2.0 * t)

    omega = jax.tree.map(comb, g0, *gs)
    slack = jnp.maximum(phi(nu), 0.0)
    return ConstrainedSolution(omega, nu, slack)

"""SSCA stepsize schedules (paper eqs. (4) and (6)).

rho^(t) = a1 / t**alpha_rho   — surrogate averaging weight, must satisfy (4):
    0 < rho <= 1,  rho -> 0,  sum rho = inf.
gamma^(t) = a2 / t**alpha_gamma — iterate stepsize, must satisfy (6):
    0 < gamma <= 1, gamma -> 0, sum gamma = inf, sum gamma^2 < inf,
    gamma/rho -> 0.

The paper's own grid-searched settings use alpha_gamma == alpha_rho (= 0.1/0.3),
which satisfies (4) but not the last two conditions of (6) in the strict limit —
they hold on any finite horizon and work empirically (paper §VI). We default to
a theory-compliant alpha_gamma = 0.6 and expose the paper's values in configs.
"""
from __future__ import annotations

import jax.numpy as jnp


def rho(t, a1: float, alpha: float):
    """t is 1-based. Returns rho^(t) clipped to (0, 1]."""
    t = jnp.maximum(t, 1).astype(jnp.float32)
    return jnp.minimum(a1 / t**alpha, 1.0)


def gamma(t, a2: float, alpha: float):
    t = jnp.maximum(t, 1).astype(jnp.float32)
    return jnp.minimum(a2 / t**alpha, 1.0)


def check_conditions(a1, a2, alpha_rho, alpha_gamma, strict=True):
    """Static sanity check of (4)/(6). Returns list of violations."""
    bad = []
    if not (0 < a1 <= 1 or alpha_rho > 0):
        bad.append("rho(1) must be in (0,1]")
    if alpha_rho <= 0 or alpha_rho > 1:
        bad.append("need 0 < alpha_rho <= 1 for rho->0 and sum rho = inf")
    if alpha_gamma <= 0 or alpha_gamma > 1:
        bad.append("need 0 < alpha_gamma <= 1 for gamma->0 and sum gamma = inf")
    if strict:
        if 2 * alpha_gamma <= 1:
            bad.append("sum gamma^2 < inf requires alpha_gamma > 0.5")
        if alpha_gamma <= alpha_rho:
            bad.append("gamma/rho -> 0 requires alpha_gamma > alpha_rho")
    return bad

"""Pytree arithmetic helpers shared across the SSCA stack.

One home for the small linear-algebra-over-pytrees vocabulary (axpy, inner
products, zeros) that the surrogate recursion (eq. 9), the closed-form
solvers (Lemma 1, Problems 5/10), the optimizer steps, and the baselines all
speak. Everything here is pure jnp over `jax.tree` — jit/vmap/scan/shard_map
transparent — and accumulates in float32 regardless of leaf dtype, because
the surrogate buffers are float32 by contract (DESIGN.md §3).

`core/surrogate.py` re-exports these names for back-compat (they originally
lived there); new code should import from `repro.core.tree`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_axpy(a, x, b, y):
    """a*x + b*y over pytrees."""
    return jax.tree.map(lambda u, v: a * u + b * v, x, y)


def tree_dot(x, y):
    """Σ ⟨x_leaf, y_leaf⟩ accumulated in float32."""
    return sum(jnp.vdot(u.astype(jnp.float32), v.astype(jnp.float32))
               for u, v in zip(jax.tree.leaves(x), jax.tree.leaves(y)))


def tree_l2sq(x):
    """‖x‖² over all leaves (float32 accumulation)."""
    return tree_dot(x, x)


def tree_zeros_like(x, dtype=None):
    return jax.tree.map(lambda u: jnp.zeros_like(u, dtype=dtype or u.dtype), x)

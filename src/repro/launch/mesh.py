"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because smoke tests and benches run
with 1 real CPU device while the dry-run forces 512 virtual host devices.

Axes:
  single-pod: (16, 16)      -> ("data", "model")          256 chips (one v5e pod)
  multi-pod:  (2, 16, 16)   -> ("pod", "data", "model")   512 chips (2 pods)

FL semantics (DESIGN.md §2): the ("pod","data") shards ARE the paper's clients;
sample-based q-aggregation is the all-reduce over those axes. The "model" axis
carries tensor/expert parallelism (and the feature-based ω_i blocks).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)}; "
            "run via launch/dryrun.py which forces 512 host devices")
    import numpy as np
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_client_mesh(num_devices: int | None = None, axis: str = "data"):
    """Small 1-D client mesh for the sharded sample-based topology
    (core/topology.py): `axis` carries the paper's clients, client i lives on
    device i mod D. Defaults to ALL host devices, so CI can exercise the
    collective path with ``--xla_force_host_platform_device_count=8`` and a
    laptop gets a 1-device mesh (psum over a size-1 axis — the degenerate
    sharded topology every tier-1 run covers)."""
    import numpy as np
    devices = jax.devices()
    n = num_devices or len(devices)
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices for the client mesh, have "
                           f"{len(devices)}; set XLA_FLAGS="
                           f"--xla_force_host_platform_device_count={n}")
    return jax.sharding.Mesh(np.asarray(devices[:n]), (axis,))


def make_feature_mesh(num_devices: int | None = None):
    """1-D "model"-axis mesh for the sharded feature-based topology
    (core/topology.py feature_sum, DESIGN.md §12): each model-axis shard IS
    a vertical-FL feature client holding its ω_i block and feature slice.
    Same device policy as `make_client_mesh`."""
    return make_client_mesh(num_devices, axis="model")


def data_axes(mesh) -> tuple:
    """The axes a global-batch dimension shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def adapt_for_mesh(spec_tree, mesh):
    """Rewrites activation/cache PartitionSpecs written against the single-pod
    axis names: any 'data' entry becomes ('pod','data') on a multi-pod mesh.
    Param specs are NOT adapted — FSDP stays within a pod (DCN-frugal)."""
    if "pod" not in mesh.axis_names:
        return spec_tree
    def fix(spec):
        if not isinstance(spec, P):
            return spec
        return P(*(("pod", "data") if e == "data" else e for e in spec))
    return jax.tree.map(fix, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def fit_specs(spec_tree, shape_tree, mesh):
    """Shape-aware spec repair: any PartitionSpec entry whose mesh-axis size
    does not divide the corresponding dim is re-homed to the largest other
    unassigned dim it divides (e.g. batch=1 decode caches shard the sequence
    dim instead), else dropped. Keeps every (arch x shape x mesh) lowerable
    without per-case hand specs."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def axis_size(e):
        names = (e,) if isinstance(e, str) else tuple(e)
        n = 1
        for nm in names:
            n *= sizes.get(nm, 1)
        return n

    def fit(spec, shp):
        shape = shp.shape
        entries = list(spec) + [None] * (len(shape) - len(spec))
        out = [None] * len(shape)
        homeless = []
        seen = set()
        for i, e in enumerate(entries[: len(shape)]):
            if e is None:
                continue
            names = (e,) if isinstance(e, str) else tuple(e)
            if any(n in seen for n in names):   # an axis may appear only once
                continue
            seen.update(names)
            if shape[i] % axis_size(e) == 0 and shape[i] >= axis_size(e):
                out[i] = e
            else:
                homeless.append(e)
        for e in homeless:
            n = axis_size(e)
            cands = [i for i in range(len(shape))
                     if out[i] is None and shape[i] % n == 0 and shape[i] >= n]
            if cands:
                out[max(cands, key=lambda i: shape[i])] = e
        return P(*out)

    return jax.tree.map(fit, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


def named_fitted(mesh, spec_tree, shape_tree):
    return named(mesh, fit_specs(spec_tree, shape_tree, mesh))


def named(mesh, spec_tree):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))

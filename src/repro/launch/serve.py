"""Serving: prefill + batched one-token decode steps under pjit.

Decode shapes (decode_32k / long_500k) lower `serve_step` — ONE new token
against a seq_len-deep KV cache / SSM state — not train_step.

CLI:  PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b --smoke \
          --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import mesh as mesh_lib
from repro.models import get_model


_SEQ_CACHE_KEYS = ("k", "v", "attn_k", "attn_v", "self_k", "self_v")


def grow_cache(cache, extra: int):
    """Pad the sequence axis (axis 2: (L,B,S,KV,Hd)) of KV caches by `extra`
    slots; O(1) SSM states pass through unchanged."""
    return {k: (jnp.pad(v, ((0, 0), (0, 0), (0, extra)) + ((0, 0),) * (v.ndim - 3))
                if k in _SEQ_CACHE_KEYS else v)
            for k, v in cache.items()}


def make_decode_step(model, cfg, greedy: bool = True):
    def serve_step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos, cfg)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return next_tok, cache

    return serve_step


def jit_decode_step(model, cfg, mesh):
    step = make_decode_step(model, cfg)
    pspec = mesh_lib.named(mesh, model.param_specs(cfg, mode="serve"))
    cspec = mesh_lib.named(mesh, mesh_lib.adapt_for_mesh(model.cache_specs(cfg), mesh))
    axes = mesh_lib.data_axes(mesh)
    tspec = jax.sharding.NamedSharding(mesh, P(axes))
    rspec = jax.sharding.NamedSharding(mesh, P())
    return jax.jit(step, in_shardings=(pspec, cspec, tspec, rspec),
                   out_shardings=(tspec, cspec))


def generate(arch: str, *, smoke: bool = False, batch: int = 2,
             prompt_len: int = 32, gen: int = 16, seed: int = 0):
    """Single-host batched generation (greedy)."""
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    model = get_model(cfg)
    if not model.has_decode:
        raise ValueError(f"{arch} has no decode path")
    key = jax.random.PRNGKey(seed)
    params = model.init(key, cfg)

    batch_in = {"tokens": jax.random.randint(
        jax.random.fold_in(key, 1), (batch, prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch_in["prefix_embeddings"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (batch, cfg.num_prefix_tokens, cfg.d_model)).astype(cfg.dtype)
    if cfg.family == "audio":
        batch_in["frame_embeddings"] = jax.random.normal(
            jax.random.fold_in(key, 3),
            (batch, prompt_len * 4, cfg.d_model)).astype(cfg.dtype)

    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cfg))(params, batch_in)
    cache = grow_cache(cache, gen)   # room for the generated tokens
    step_fn = jax.jit(make_decode_step(model, cfg))

    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    pos = int(cache["pos"]) if "pos" in cache else prompt_len
    t0 = time.time()
    for i in range(gen - 1):
        tok, cache = step_fn(params, cache, tok, jnp.asarray(pos + i, jnp.int32))
        out.append(tok)
    dt = time.time() - t0
    seqs = jnp.concatenate(out, axis=1)
    return seqs, {"tokens_per_s": batch * (gen - 1) / max(dt, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    seqs, stats = generate(args.arch, smoke=args.smoke, batch=args.batch,
                           prompt_len=args.prompt_len, gen=args.gen)
    print("generated:", seqs)
    print(stats)


if __name__ == "__main__":
    main()

"""Distributed feature-based (vertical) FL: Algorithm 3 on the "model" mesh
axis via shard_map — the faithful realization of DESIGN.md §2's mapping.

Each model-axis shard IS a feature client: it holds its parameter block ω_i
and feature slice x_{n,i} locally; the paper's step-4 h-exchange is a psum
over the "model" axis (each client contributes its partial pre-activation);
the head gradient (step 5) is computed redundantly on every shard from the
aggregated h (no distinguished "fastest client" needed on a synchronous
mesh); step 6's block gradients never leave their shard. The server update
(steps 7-8, closed form (24)+(18)) is elementwise: replicated for ω_0,
shard-local for each ω_i.

Per-round bytes over the "model" axis: B·J floats (the h psum) + the ω_0
gradient reduction — exactly the paper's communication-load accounting for
Algorithm 3 (Remark 3/4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import optimizer


def make_feature_round(mesh, head_loss_from_h, client_h):
    """Returns round_fn(w0, blocks, zb, yb) -> (grad_w0, grad_blocks, loss).

    blocks: (I, ...) client parameter blocks, sharded over "model" (I = axis
    size); zb: (I, B, P_i) per-client feature slices, same sharding; yb:
    (B, L) labels, replicated (supervised vertical FL: all clients hold y).
    """

    def round_local(w0, blocks, zb, yb):
        # step 4: local partial pre-activation, exchanged via psum
        h_local = client_h(blocks[0], zb[0])                  # (B, J)
        h_sum = jax.lax.psum(h_local, "model")

        # step 5: head stats from aggregated h only (replicated compute)
        def head_mean_loss(w0_, h_):
            return jnp.mean(head_loss_from_h(w0_, h_, yb))

        loss, gw0 = jax.value_and_grad(head_mean_loss)(w0, h_sum)

        # step 6: chain rule through this client's own h_i — stays local
        dl_dh = jax.grad(lambda h_: head_mean_loss(w0, h_))(h_sum)
        _, vjp = jax.vjp(lambda bl: client_h(bl, zb[0]), blocks[0])
        gblock = vjp(dl_dh)[0][None]                          # (1, ...)
        return gw0, gblock, loss

    return shard_map(
        round_local, mesh=mesh,
        in_specs=(P(), P("model"), P("model"), P()),
        out_specs=(P(), P("model"), P()),
        check_rep=False)


def train_feature_distributed(mesh, head_loss_from_h, client_h, w0, blocks,
                              feature_blocks, labels, fl, rounds: int, key):
    """Runs Algorithm 3 with ω_i resident on their model-axis shards."""
    round_fn = make_feature_round(mesh, head_loss_from_h, client_h)
    params = {"w0": w0, "blocks": blocks}
    state = optimizer.ssca_init(params)
    n = labels.shape[0]

    @jax.jit
    def step(state, k):
        idx = jax.random.randint(k, (fl.batch_size,), 0, n)
        zb = jnp.take(feature_blocks, idx, axis=1)
        yb = jnp.take(labels, idx, axis=0)
        gw0, gblocks, loss = round_fn(state.params["w0"],
                                      state.params["blocks"], zb, yb)
        grads = {"w0": gw0, "blocks": gblocks}
        return optimizer.ssca_step(state, grads, fl), loss

    losses = []
    with mesh:
        for t in range(rounds):
            key, sub = jax.random.split(key)
            state, loss = step(state, sub)
            if (t + 1) % max(rounds // 10, 1) == 0:
                losses.append(float(loss))
    return state.params, losses

"""DEPRECATED shim — the bespoke shard_map vertical-FL path now lives on the
shared topology + scan engine.

This module used to carry its own shard_map/mesh helpers for Algorithm 3 on
the "model" mesh axis. That private fork is retired: the same mapping (each
model-axis shard IS a feature client, DESIGN.md §2/§12) is realized by
``repro.core.topology.ShardedTopology.feature_sum`` — with the step-4
h-exchange as a tiled all_gather instead of a psum, so sharded == local is
bit-exact — driven by ``repro.core.rounds.run_feature_rounds`` and
``repro.core.algorithms.algorithm3/4``. Mesh construction moved to
``repro.launch.mesh.make_feature_mesh``; the training CLI is
``repro.launch.train --mode feature``.

The two public entry points below keep their historical signatures and
semantics (mean-scaled gradients, ~10 checkpoint losses) as thin wrappers
over the shared engine, so existing callers keep working; new code should
use the shared stack directly.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.core.topology import ShardedTopology


def _deprecated(name: str, repl: str):
    warnings.warn(
        f"[FLT004] repro.launch.feature_dist.{name} is deprecated; use {repl} "
        "(the shared topology + scan engine, DESIGN.md §12) — the training "
        "CLI is `python -m repro.launch.train --mode feature` "
        "(flagged by `python -m repro.analysis`)",
        DeprecationWarning, stacklevel=3)


def make_feature_round(mesh, head_loss_from_h, client_h):
    """Returns round_fn(w0, blocks, zb, yb) -> (grad_w0, grad_blocks, loss)
    with MEAN-loss scaling (the historical contract of this module).

    Deprecated: build a `ShardedTopology(mesh, axes=("model",))` and call
    `fed.feature_round(..., topology=...)` instead (1/B-scaled eq.-16
    semantics, codec/EF support, uploads surface).
    """
    _deprecated("make_feature_round",
                "repro.core.fed.feature_round(topology=...)")
    topo = ShardedTopology(mesh, axes=("model",))

    def round_fn(w0, blocks, zb, yb):
        def head_fn(h_sum):
            def head_mean_loss(w0_, h_):
                return jnp.mean(head_loss_from_h(w0_, h_, yb))

            loss, gw0 = jax.value_and_grad(head_mean_loss)(w0, h_sum)
            dl_dh = jax.grad(lambda h_: head_mean_loss(w0, h_))(h_sum)
            return loss, gw0, dl_dh

        def block_grad(block_i, zb_i, dl_dh):
            _, vjp = jax.vjp(lambda bl: client_h(bl, zb_i), block_i)
            return vjp(dl_dh)[0]

        s = topo.feature_sum(client_h, head_fn, block_grad, blocks, zb)
        return s.q_head, s.q_blocks, s.value

    return round_fn


def train_feature_distributed(mesh, head_loss_from_h, client_h, w0, blocks,
                              feature_blocks, labels, fl, rounds: int, key):
    """Runs Algorithm 3 with ω_i resident on their model-axis shards.
    Returns (params, ~10 checkpoint batch-loss floats), as always.

    Deprecated: call `repro.core.algorithms.algorithm3(...,
    topology=ShardedTopology(mesh, axes=("model",)))` directly — scan-
    compiled rounds, full per-round history, codec support.
    """
    _deprecated("train_feature_distributed",
                "repro.core.algorithms.algorithm3(topology=...)")
    from repro.core import algorithms, fed

    topo = ShardedTopology(mesh, axes=("model",))
    data = fed.FeatureFedData(feature_blocks, labels)
    r = algorithms.algorithm3(head_loss_from_h, client_h,
                              {"w0": w0, "blocks": blocks}, data, fl, rounds,
                              key, eval_every=0, topology=topo)
    ck = max(rounds // 10, 1)
    le = r.history["round_loss_est"]
    losses = [float(le[t]) for t in range(ck - 1, rounds, ck)]
    return r.params, losses

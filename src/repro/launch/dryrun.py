import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against the production mesh with ShapeDtypeStruct stand-ins (no
allocation), printing memory_analysis / cost_analysis and the roofline terms.

MUST keep the two lines above as the very first statements — jax locks the
device count on first init, and smoke tests/benches must still see 1 device
(this env var is process-local to the dry-run).

CLI:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]
"""
import argparse       # noqa: E402
import json           # noqa: E402
import sys            # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402
import jax.numpy as jnp                      # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import FLConfig, get_config           # noqa: E402
from repro.configs.registry import ARCHS, ASSIGNED       # noqa: E402
from repro.configs.shapes import SHAPES, supports_shape  # noqa: E402
from repro.configs import shapes as shapes_lib           # noqa: E402
from repro.core import optimizer                         # noqa: E402
from repro.launch import mesh as mesh_lib                # noqa: E402
from repro.launch import serve as serve_lib              # noqa: E402
from repro.launch import train as train_lib              # noqa: E402
from repro.models import get_model                       # noqa: E402
from repro.roofline import (HW, collective_bytes_from_hlo,  # noqa: E402
                            model_flops, roofline_terms)
from repro.roofline.analysis import active_params, count_params  # noqa: E402


def _state_shapes(model, cfg, constrained: bool):
    """SSCA train state as ShapeDtypeStructs (init evaluated shape-only)."""
    def build():
        params = model.init(jax.random.PRNGKey(0), cfg)
        return (optimizer.ssca_constrained_init(params) if constrained
                else optimizer.ssca_init(params))
    return jax.eval_shape(build)


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              constrained: bool = False, fl: FLConfig = None, verbose: bool = True,
              overrides: dict = None):
    """Lower + compile one (arch, shape, mesh). Returns result dict.
    overrides: ModelConfig field overrides (the §Perf hillclimb knobs)."""
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        typed = {}
        for k, v in overrides.items():
            fld = {f.name: f.type for f in dataclasses.fields(cfg)}[k]
            if isinstance(v, str):
                if v.lower() in ("true", "false"):
                    v = v.lower() == "true"
                elif v.lstrip("-").isdigit():
                    v = int(v)
            typed[k] = v
        cfg = dataclasses.replace(cfg, **typed)
    shape = SHAPES[shape_name]
    ok, why = supports_shape(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "why": why}

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    model = get_model(cfg)
    fl = fl or FLConfig(tau=0.2, l2_lambda=1e-5)
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            batch = shapes_lib.train_specs(cfg, shape)
            state = _state_shapes(model, cfg, constrained)
            step = (train_lib.make_constrained_train_step if constrained
                    else train_lib.make_train_step)(model, cfg, fl)
            sspec = mesh_lib.named_fitted(
                mesh, train_lib.state_specs(model, cfg, constrained), state)
            bspec = mesh_lib.named_fitted(
                mesh, train_lib.batch_specs(batch, mesh), batch)
            lowered = jax.jit(step, in_shardings=(sspec, bspec),
                              out_shardings=(sspec, None),
                              donate_argnums=(0,)).lower(state, batch)
            num_tokens = shape.global_batch * shape.seq_len
        elif shape.kind == "prefill":
            batch = shapes_lib.prefill_specs(cfg, shape)
            params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), cfg))
            pspec = mesh_lib.named_fitted(
                mesh, model.param_specs(cfg, mode="serve"), params)
            bspec = mesh_lib.named_fitted(
                mesh, train_lib.batch_specs(batch, mesh), batch)
            lowered = jax.jit(
                lambda p, b: model.prefill(p, b, cfg),
                in_shardings=(pspec, bspec)).lower(params, batch)
            num_tokens = shape.global_batch * shape.seq_len
        else:  # decode
            token, pos, cache = shapes_lib.decode_specs(cfg, shape)
            params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), cfg))
            step = serve_lib.make_decode_step(model, cfg)
            pspec = mesh_lib.named_fitted(
                mesh, model.param_specs(cfg, mode="serve"), params)
            cspec = mesh_lib.named_fitted(
                mesh, mesh_lib.adapt_for_mesh(model.cache_specs(cfg), mesh), cache)
            axes = mesh_lib.data_axes(mesh)
            tspec = mesh_lib.named_fitted(mesh, P(axes), token)
            rspec = jax.sharding.NamedSharding(mesh, P())
            lowered = jax.jit(step, in_shardings=(pspec, cspec, tspec, rspec),
                              out_shardings=(tspec, cspec),
                              donate_argnums=(1,)).lower(params, cache, token, pos)
            num_tokens = shape.global_batch      # one new token per sequence

        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        from repro.roofline import hlo_cost
        # raw XLA numbers (while bodies x1); list/dict + key drift normalized
        cost_xla = hlo_cost.xla_cost_analysis(compiled)
        parsed = hlo_cost.analyze(hlo)           # while-aware (see roofline/hlo_cost.py)
        coll = parsed["collectives"]
        coll.setdefault("total", 0.0)
        terms = roofline_terms(
            {"flops": parsed["flops"], "bytes accessed": parsed["bytes"]},
            coll["total"])

        params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), cfg))
        n_params = count_params(params_shape)
        n_active = active_params(cfg, params_shape)
        chips = mesh.devices.size
        mflops = model_flops(cfg, num_tokens, n_params, n_active)
        if shape.kind == "train":
            mflops *= 1.0        # 6ND already includes fwd+bwd
        else:
            mflops /= 3.0        # forward only: 2ND
        useful = mflops / chips / max(terms["flops"], 1e-30)

        result = {
            "arch": arch, "shape": shape_name, "kind": shape.kind,
            "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
            "status": "ok", "compile_s": round(t_compile, 1),
            "params": n_params, "active_params": n_active,
            "model_flops_per_chip": mflops / chips,
            "useful_flop_ratio": useful,
            "memory": {
                "bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
            "collectives": coll,
            "xla_raw": {"flops": cost_xla.get("flops"),
                        "bytes": cost_xla.get("bytes accessed")},
            **{k: terms[k] for k in ("flops", "bytes", "collective_bytes",
                                     "compute_s", "memory_s", "collective_s",
                                     "bottleneck")},
        }
        if verbose:
            print(f"[{result['mesh']}] {arch} x {shape_name}: OK "
                  f"compile={t_compile:.0f}s bottleneck={result['bottleneck']} "
                  f"compute={terms['compute_s']*1e3:.2f}ms "
                  f"memory={terms['memory_s']*1e3:.2f}ms "
                  f"collective={terms['collective_s']*1e3:.2f}ms "
                  f"useful={useful:.2f}", flush=True)
        return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--constrained", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig override, e.g. --set attention_impl=chunked")
    args = ap.parse_args()
    overrides = dict(s.split("=", 1) for s in args.set)

    combos = []
    archs = ASSIGNED if args.all else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                combos.append((a, s, mp))

    results, failures = [], []
    for a, s, mp in combos:
        try:
            r = lower_one(a, s, multi_pod=mp, constrained=args.constrained,
                          overrides=overrides)
        except Exception as e:
            traceback.print_exc()
            r = {"arch": a, "shape": s, "mesh": "2x16x16" if mp else "16x16",
                 "status": "error", "error": f"{type(e).__name__}: {e}"}
            failures.append(r)
            print(f"[{'2x16x16' if mp else '16x16'}] {a} x {s}: FAIL {e}",
                  flush=True)
        results.append(r)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    print(f"\n== dry-run: {n_ok} ok, {n_skip} skipped, {len(failures)} failed "
          f"of {len(results)}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, default=str)
        print("wrote", args.json)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

"""Distributed training: the SSCA federated optimizer wrapped around any zoo
model under pjit. The per-round client upload/aggregate of Algorithm 1/2 is
realized by the data-axis all-reduce that pjit inserts for the batch-mean
gradient (clients = data shards, equal N_i; see DESIGN.md §2/§7).

The single-host driver is scan-compiled (DESIGN.md §6): batch selection,
gradient, and the SSCA update for a whole log interval run as ONE ``lax.scan``
dispatch via core/rounds.py, with the ρ^t/γ^t schedules threaded as scan
inputs. ``--driver loop`` keeps the seed's one-dispatch-per-step execution
for comparison (benchmarks/rounds_bench.py quantifies the gap).

Upload compression (DESIGN.md §10): ``--codec {none,int8,int4,topk}`` runs
the round's gradient "upload" through a repro.comm codec with an
error-feedback residual carried in the scan state (CommCarry) — in the
clients-as-data-shards picture this compresses exactly what Algorithm 1's
clients put on the wire, and the logged ``upload_bytes`` is the per-round
wire cost from repro.comm.accounting.

Client topology (DESIGN.md §11): ``--topology sharded`` makes the
clients-as-data-shards picture *explicit* — the per-round batch is split
into ``--shards`` equal client shards distributed over a 1-D device mesh via
core/topology.py's shard_map engine, each shard computes its local gradient
(and codec/EF compresses it at the client boundary), and the Algorithm-1
aggregation is a weighted psum over the mesh. ``--topology local`` (default)
keeps the single-dispatch pjit picture unchanged.

Feature-based (vertical FL) mode (DESIGN.md §12): ``--mode feature`` runs
Algorithm 3 — or Algorithm 4 with ``--constrained`` (min ‖ω‖² s.t.
mean-loss <= ``--cost-limit``, formulation (40)) — on a synthetic
classification task with the features split into ``--clients`` vertical
blocks. ``--topology sharded`` places each feature client on its own
"model"-axis shard (`launch.mesh.make_feature_mesh`) with the h-exchange
as a tiled all_gather; the codec flags compress the head + block q-uploads
exactly as in core/algorithms.py.

Observability (DESIGN.md §13): ``--log-jsonl out.jsonl`` streams per-round
rows (loss, stationarity residual, upload bytes, ...) to disk WHILE the scan
runs via the obs/ MetricStream tap, writes a run manifest (config, mesh,
codec, per-dispatch HLO cost) next to it, and interleaves host-span timing
rows; ``--log-every N`` thins the stream; ``--profile DIR`` wraps the run in
a jax.profiler trace whose timeline carries the protocol phase annotations.

Cohort mode (DESIGN.md §14): ``--mode cohort --clients 1000000
--participation 256`` runs horizontal FL over a VIRTUAL population — client
shards derived on the fly from the client id (`data.synthetic
.VirtualFedData`), the S-client cohort drawn in O(S) by a keyed Feistel
permutation, EF residuals in a keyed store gathered/scattered per round —
so per-round compute and state scale with S while I goes to a million.

Differential privacy (DESIGN.md §15): ``--dp-epsilon 4 [--dp-delta 1e-5
--dp-clip 1.0]`` clips + Gaussian-noises every gradient upload at the
client boundary BEFORE the codec (analytic Gaussian calibration), streams
dp_epsilon (the subsampled-RDP accountant's composed ε-so-far), clip
fraction, and noise norm per round, and records the full accounting in the
run manifest. Works in every mode; cohort mode's S-of-I draw earns the
subsampling amplification.

CLI:  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
          --steps 100 --batch 8 --seq 512 [--constrained] [--smoke] \
          [--driver scan|loop] [--codec int8] [--topk-frac 0.01] \
          [--codec-impl pallas] [--topology local|sharded] [--shards 8] \
          [--log-jsonl out.jsonl --log-every 1 --profile prof/]
      PYTHONPATH=src python -m repro.launch.train --mode feature \
          --clients 4 --steps 200 [--constrained --cost-limit 1.2] \
          [--topology sharded] [--codec int8] [--driver scan|loop]
      PYTHONPATH=src python -m repro.launch.train --mode cohort \
          --clients 1000000 --participation 256 --steps 100 \
          [--constrained] [--codec int8] [--topology sharded]
"""
from __future__ import annotations

import argparse
import contextlib
import time
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import (CommCarry, ef_init, ef_init_stacked, ef_roundtrip,
                        flatten_tree, make_codec, tree_flat_dim,
                        with_comm_carry)
from repro.configs import FLConfig, get_config
from repro.core import optimizer, rounds
from repro.core import privacy as privacy_lib
from repro.core import topology as topology_lib
from repro.launch import mesh as mesh_lib
from repro.models import get_model
from repro.obs import metrics as obs_metrics
from repro.obs import sinks as obs_sinks
from repro.obs import trace as obs_trace


def _make_stream(log_jsonl, log_stream_every, profile_dir, name):
    """Observability trio for a training loop: MetricStream (JSONL when
    ``log_jsonl`` is set), HostSpans bound to it, and the profiler context
    (nullcontext unless ``profile_dir``). Always returns a live stream so
    span rows have somewhere to go; with no sinks it is just an in-memory
    row buffer."""
    sinks = [obs_sinks.JsonlSink(log_jsonl)] if log_jsonl else []
    stream = obs_metrics.MetricStream(sinks, log_every=log_stream_every,
                                      name=name)
    spans = obs_trace.HostSpans(stream)
    prof = (obs_trace.profile(profile_dir) if profile_dir
            else contextlib.nullcontext())
    return stream, spans, prof


def _ssca_update(state, loss, grads, fl: FLConfig, rho_t, gamma_t,
                 constrained: bool):
    """Shared update + metrics of the (constrained) train step — single
    definition so the codec path below cannot drift from the dense one."""
    if constrained:
        new = optimizer.ssca_constrained_step(state, grads, loss, fl,
                                              rho_t=rho_t, gamma_t=gamma_t)
        return new, {"loss": loss, "nu": new.nu, "slack": new.slack,
                     "l2": sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                               for x in jax.tree.leaves(new.params))}
    new = optimizer.ssca_step(state, grads, fl, rho_t=rho_t, gamma_t=gamma_t)
    return new, {"loss": loss, "t": state.t}


def make_train_step(model, cfg, fl: FLConfig):
    """Returns train_step(state, batch[, rho_t, gamma_t]) -> (state, metrics).
    Unconstrained Algorithm-1-example update (= momentum SGD w/ diminishing
    stepsizes). rho_t/gamma_t default to the state.t-derived schedule; the
    scan driver passes them precomputed per round."""

    def train_step(state, batch, rho_t=None, gamma_t=None):
        loss, grads = jax.value_and_grad(model.loss_fn)(state.params, batch, cfg)
        return _ssca_update(state, loss, grads, fl, rho_t, gamma_t,
                            constrained=False)

    return train_step


def make_constrained_train_step(model, cfg, fl: FLConfig):
    """Algorithm-2-example: min ‖ω‖² s.t. mean-loss <= U (formulation (40))."""

    def train_step(state, batch, rho_t=None, gamma_t=None):
        loss, grads = jax.value_and_grad(model.loss_fn)(state.params, batch, cfg)
        return _ssca_update(state, loss, grads, fl, rho_t, gamma_t,
                            constrained=True)

    return train_step


def state_specs(model, cfg, constrained: bool):
    ps = model.param_specs(cfg, mode="train")
    if constrained:
        return optimizer.SSCAConstrainedState(
            params=ps,
            cons=optimizer.QuadSurrogate(d=P(), g=ps),
            t=P(), nu=P(), slack=P())
    return optimizer.SSCAState(params=ps, g=ps, t=P())


def batch_specs(batch_tree, mesh):
    axes = mesh_lib.data_axes(mesh)
    return jax.tree.map(lambda _: P(axes), batch_tree)


def jit_train_step(model, cfg, fl, mesh, batch_like, constrained=False):
    step = (make_constrained_train_step if constrained else make_train_step)(
        model, cfg, fl)
    sspec = mesh_lib.named(mesh, state_specs(model, cfg, constrained))
    bspec = mesh_lib.named(mesh, batch_specs(batch_like, mesh))
    return jax.jit(step, in_shardings=(sspec, bspec),
                   out_shardings=(sspec, None))


# ---------------------------------------------------------------------------
# single-host training driver (CPU-runnable with reduced configs)
# ---------------------------------------------------------------------------


def make_scanned_step(model, cfg, fl: FLConfig, tokens, batch: int, seq: int,
                      constrained: bool = False, codec=None, topology=None,
                      dp=None):
    """Fuses per-round data selection into the train step so the whole round
    chain is scannable: step(state, RoundInputs) -> (state, metrics). With a
    codec, the gradient is compressed through an error-feedback roundtrip
    before the SSCA update and the state is a CommCarry.

    With a sharded ``topology`` the batch is reshaped into D equal client
    shards and the gradient (+ loss) estimate is computed by the topology
    engine — per-shard value_and_grad, per-shard codec/EF (residuals become
    an (D, P) matrix in the CommCarry), equal-weight 1/D psum aggregation.
    The local path is byte-identical to before.

    ``dp=`` (privacy.DPConfig) clips+noises the gradient upload(s) before
    any codec encode (DESIGN.md §15) — per shard on the sharded path, on
    the single all-reduced gradient on the local path — and adds the dp_*
    metrics (all shards release every round, so the accountant runs at
    q = 1)."""
    from repro.data.synthetic import sample_window

    eps_fn = privacy_lib.make_eps_fn(dp, 1.0) if dp is not None else None
    shards = getattr(topology, "num_shards", 1) if topology is not None else 1
    if topology is not None and topology.name == "sharded":
        if batch % shards:
            raise ValueError(f"--batch {batch} must be divisible by the "
                             f"{shards} client shards of --topology sharded")

        def sharded_body(state, inp, ef):
            data = sample_window(tokens, inp.key, batch, seq)
            shard = jax.tree.map(
                lambda x: x.reshape((shards, batch // shards) + x.shape[1:]),
                data)

            def client_fn(b):
                loss, grads = jax.value_and_grad(model.loss_fn)(
                    state.params, b, cfg)
                return grads, loss

            ckeys = (jax.random.split(jax.random.fold_in(inp.key, 0xC0DEC),
                                      shards) if codec is not None else None)
            dkeys = (jax.random.split(jax.random.fold_in(inp.key, 0xD9),
                                      shards) if dp is not None else None)
            w = jnp.full((shards,), 1.0 / shards, jnp.float32)
            s = topology.weighted_sum(client_fn, (shard,), w, codec=codec,
                                      ef=ef, codec_keys=ckeys, dp=dp,
                                      dp_keys=dkeys)
            new, metrics = _ssca_update(state, s.value, s.weighted, fl,
                                        inp.rho, inp.gamma, constrained)
            if codec is not None:
                metrics["upload_bytes"] = float(
                    shards * codec.nbytes(tree_flat_dim(state.params)))
            if dp is not None:
                metrics["dp_epsilon"] = eps_fn(inp.t)
                metrics["dp_clip_frac"] = jnp.mean(s.dp["clipped"])
                metrics["dp_noise_norm"] = jnp.sqrt(
                    jnp.sum(s.dp["noise_sq"]))
            return new, s.ef, metrics

        return with_comm_carry(codec, sharded_body)

    train_step = (make_constrained_train_step if constrained
                  else make_train_step)(model, cfg, fl)

    def step(state, inp):
        data = sample_window(tokens, inp.key, batch, seq)
        return train_step(state, data, rho_t=inp.rho, gamma_t=inp.gamma)

    if codec is None and dp is None:
        return step

    def comm_body(state, inp, ef):
        data = sample_window(tokens, inp.key, batch, seq)
        loss, grads = jax.value_and_grad(model.loss_fn)(state.params, data,
                                                        cfg)
        gf, unflatten = flatten_tree(grads)
        metrics_dp = None
        if dp is not None:
            gf, dstats = privacy_lib.privatize_flat(
                gf, jax.random.fold_in(inp.key, 0xD9), dp)
            metrics_dp = {"dp_epsilon": eps_fn(inp.t),
                          "dp_clip_frac": dstats["clipped"],
                          "dp_noise_norm": jnp.sqrt(dstats["noise_sq"])}
        if codec is not None:
            _, g_hat, new_ef = ef_roundtrip(
                codec, gf, ef, jax.random.fold_in(inp.key, 0xC0DEC))
        else:
            g_hat, new_ef = gf, ef
        new, metrics = _ssca_update(state, loss, unflatten(g_hat), fl,
                                    inp.rho, inp.gamma, constrained)
        if codec is not None:
            metrics["upload_bytes"] = float(codec.nbytes(gf.shape[0]))
        if metrics_dp is not None:
            metrics.update(metrics_dp)
        return new, new_ef, metrics

    return with_comm_carry(codec, comm_body)


def train_loop(arch: str, steps: int, batch: int, seq: int, *,
               smoke: bool = False, constrained: bool = False,
               fl: Optional[FLConfig] = None, log_every: int = 10,
               ckpt_path: Optional[str] = None, seed: int = 0,
               driver: str = "scan", codec: Optional[str] = None,
               topk_frac: float = 0.01, codec_impl: str = "ref",
               topology: str = "local", shards: Optional[int] = None,
               log_jsonl: Optional[str] = None, log_stream_every: int = 1,
               profile_dir: Optional[str] = None,
               dp: Optional[privacy_lib.DPConfig] = None):
    from repro.data.synthetic import token_dataset

    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    fl = fl or FLConfig(a1=0.9, a2=0.5, alpha_rho=0.1, alpha_gamma=0.6,
                        tau=0.2, l2_lambda=1e-5, cost_limit=3.0)
    model = get_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init(key, cfg)
    state = (optimizer.ssca_constrained_init(params) if constrained
             else optimizer.ssca_init(params))
    topo = topology_lib.make_topology(
        topology, mesh=(mesh_lib.make_client_mesh(shards)
                        if topology == "sharded" else None))
    codec_obj = make_codec(codec, topk_frac=topk_frac, impl=codec_impl)
    if codec_obj is not None:
        dim = tree_flat_dim(params)
        ef0 = (ef_init_stacked(topo.num_shards, dim)
               if topo.name == "sharded" else ef_init(dim))
        state = topo.place_state(CommCarry(opt=state, ef=ef0))

    toks = token_dataset(jax.random.fold_in(key, 1), cfg.vocab_size,
                         n_tokens=max(200_000, batch * (seq + 1) * 4))
    step_fn = make_scanned_step(model, cfg, fl, toks, batch, seq, constrained,
                                codec=codec_obj, topology=topo, dp=dp)
    engine = rounds.ENGINES[driver]
    sizes = rounds.chunk_sizes(steps, log_every)

    stream, spans, prof = _make_stream(log_jsonl, log_stream_every,
                                       profile_dir, name=arch)
    if log_jsonl:
        from repro.roofline.analysis import jit_cost_summary
        probe = jax.tree.map(
            lambda x: x[0],
            rounds.make_inputs(fl, 1, 1, jax.random.fold_in(key, 3)))
        obs_sinks.write_manifest(
            log_jsonl + ".manifest.json",
            config={"arch": arch, "steps": steps, "batch": batch, "seq": seq,
                    "constrained": constrained, "driver": driver,
                    "smoke": smoke, "seed": seed},
            codec=codec_obj, topology=topo,
            cost=jit_cost_summary(step_fn, state, probe),
            extra=({"dp": privacy_lib.manifest_info(dp, 1.0, rounds=steps)}
                   if dp is not None else None))

    logs = []
    t0, done = 1, 0
    key_run = jax.random.fold_in(key, 2)
    wall0 = time.time()
    with prof:
        for size in sizes:
            key_run, sub = jax.random.split(key_run)
            inputs = rounds.make_inputs(fl, t0, size, sub)
            with spans.span("dispatch", rounds=size, t0=t0):
                state, ms = stream.run(step_fn, state, inputs, driver=driver) \
                    if log_jsonl else engine(step_fn, state, inputs)
            t0 += size
            done += size
            m = {k: float(v[-1]) for k, v in ms.items()}
            m["step"] = done
            m["wall_s"] = time.time() - wall0
            logs.append(m)
            print(" ".join(f"{k}={v:.4g}" if isinstance(v, float)
                           else f"{k}={v}" for k, v in m.items()), flush=True)
    if ckpt_path:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(ckpt_path, rounds.unwrap_comm(state).params,
                        step=steps)
    stream.close()
    return state, logs


# ---------------------------------------------------------------------------
# feature-based (vertical FL) training driver — Algorithms 3/4 on the shared
# topology + scan engine (DESIGN.md §12)
# ---------------------------------------------------------------------------


def feature_train_loop(*, clients: int = 4, rounds: int = 200,
                       batch: int = 64, features: int = 128,
                       classes: int = 10, hidden: int = 32, n: int = 8000,
                       constrained: bool = False, cost_limit: float = 1.2,
                       topology: str = "local", codec: Optional[str] = None,
                       topk_frac: float = 0.01, codec_impl: str = "ref",
                       driver: str = "scan", log_every: int = 20,
                       seed: int = 0, fl: Optional[FLConfig] = None,
                       log_jsonl: Optional[str] = None,
                       log_stream_every: int = 1,
                       profile_dir: Optional[str] = None,
                       dp: Optional[privacy_lib.DPConfig] = None):
    """Vertical-FL driver: synthetic classification, features split into
    `clients` blocks, MLP head composition (models/mlp.py), Algorithm 3 or
    (constrained) Algorithm 4 via run_feature_rounds. Returns the RunResult.
    """
    from repro.core import algorithms, fed
    from repro.core.rounds import unwrap_comm
    from repro.data.synthetic import classification_dataset
    from repro.models import mlp

    key = jax.random.PRNGKey(seed)
    (z, y, _), _ = classification_dataset(key, n=n, num_features=features,
                                          num_classes=classes, test_n=10,
                                          noise=4.0)
    data = fed.partition_features(z, y, clients)
    pi = data.feature_blocks.shape[-1]
    params0 = {"w0": jax.random.normal(key, (classes, hidden)) * 0.2,
               "blocks": jax.random.normal(jax.random.fold_in(key, 1),
                                           (clients, hidden, pi)) * 0.2}
    fl = fl or FLConfig(batch_size=batch, a1=0.9, a2=0.5, alpha_rho=0.1,
                        alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5,
                        mode="feature", constrained=constrained,
                        cost_limit=cost_limit, penalty_c=1e4)
    topo = (topology_lib.feature_sharded_for(clients)
            if topology == "sharded" else None)
    codec_obj = make_codec(codec, topk_frac=topk_frac, impl=codec_impl)

    def eval_fn(p, s):
        hsum = sum(mlp.client_h(p["blocks"][i], data.feature_blocks[i])
                   for i in range(clients))
        loss = float(jnp.mean(mlp.per_sample_loss_from_h(p["w0"], hsum, y)))
        m = {"loss": loss}
        if constrained:
            m["nu"], m["slack"] = float(s_nu(s)), float(s_slack(s))
        return m

    def s_nu(s):
        return unwrap_comm(s).nu

    def s_slack(s):
        return unwrap_comm(s).slack

    alg = algorithms.algorithm4 if constrained else algorithms.algorithm3
    stream, spans, prof = _make_stream(log_jsonl, log_stream_every,
                                       profile_dir, name="feature")
    if log_jsonl:
        obs_sinks.write_manifest(
            log_jsonl + ".manifest.json",
            config={"mode": "feature", "clients": clients, "rounds": rounds,
                    "batch": batch, "features": features, "classes": classes,
                    "hidden": hidden, "n": n, "constrained": constrained,
                    "cost_limit": cost_limit, "driver": driver, "seed": seed},
            codec=codec_obj, topology=topo,
            extra=({"dp": privacy_lib.manifest_info(
                dp, 1.0, rounds=rounds, releases_per_round=2)}
                if dp is not None else None))
    wall0 = time.time()
    with prof, spans.span("run", rounds=rounds):
        result = alg(mlp.per_sample_loss_from_h, mlp.client_h, params0, data,
                     fl, rounds, jax.random.fold_in(key, 2), eval_fn=eval_fn,
                     eval_every=log_every, driver=driver, codec=codec_obj,
                     topology=topo, obs=stream if log_jsonl else None, dp=dp)
    stream.close()
    for i, r in enumerate(result.history["round"]):
        line = {k: float(v[i]) for k, v in result.history.items()
                if not k.startswith("round")}
        line["round"] = int(r)
        print(" ".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in line.items()), flush=True)
    shards = topo.num_shards if topo is not None else 1
    print(f"done: {rounds} rounds, {shards} client shard(s), "
          f"{time.time() - wall0:.1f}s", flush=True)
    return result


# ---------------------------------------------------------------------------
# cohort-engine (million-client horizontal FL) training driver — DESIGN.md §14
# ---------------------------------------------------------------------------


def cohort_train_loop(*, clients: int = 100_000, participation: int = 256,
                      rounds: int = 200, batch: int = 16, features: int = 32,
                      classes: int = 4, hidden: int = 16,
                      constrained: bool = False, cost_limit: float = 1.2,
                      topology: str = "local", codec: Optional[str] = None,
                      topk_frac: float = 0.01, codec_impl: str = "ref",
                      driver: str = "scan", log_every: int = 20,
                      seed: int = 0, fl: Optional[FLConfig] = None,
                      log_jsonl: Optional[str] = None,
                      log_stream_every: int = 1,
                      profile_dir: Optional[str] = None,
                      dp: Optional[privacy_lib.DPConfig] = None):
    """Million-client horizontal FL driver: a `VirtualFedData` population of
    ``clients`` ragged Dirichlet-skewed shards (never materialized — every
    row derives from the client id), Algorithm 1 (or 2 with --constrained)
    through the participant-only O(S) cohort engine. Per-round compute,
    uploads, and EF state scale with ``participation``, not ``clients`` —
    ``--clients 1000000 --participation 256`` runs on a laptop. Returns the
    RunResult."""
    from repro.core import algorithms
    from repro.data.synthetic import VirtualFedData
    from repro.models import mlp

    key = jax.random.PRNGKey(seed)
    data = VirtualFedData(jax.random.fold_in(key, 0xDA7A), clients,
                          num_features=features, num_classes=classes,
                          noise=4.0)
    params0 = mlp.init(jax.random.fold_in(key, 1), features, hidden, classes)
    fl = fl or FLConfig(batch_size=batch, a1=0.9, a2=0.5, alpha_rho=0.1,
                        alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5,
                        constrained=constrained, cost_limit=cost_limit,
                        penalty_c=1e4)
    # a sharded topology splits the COHORT over devices — the population
    # size never constrains the mesh fit
    topo = (topology_lib.sharded_for(participation)
            if topology == "sharded" else None)
    codec_obj = make_codec(codec, topk_frac=topk_frac, impl=codec_impl)

    # fixed O(1)-sized eval probe: the first 64 clients' shards, masked mean
    eval_ids = jnp.arange(min(64, clients), dtype=jnp.int32)
    ez, ey, ec = data.shards_for(eval_ids)
    emask = (jnp.arange(ez.shape[1])[None, :] < ec[:, None]).astype(jnp.float32)

    def eval_fn(p, s):
        per_row = jax.vmap(lambda z, y: mlp.per_sample_loss(p, z, y))(ez, ey)
        return {"loss": float(jnp.sum(per_row * emask) / jnp.sum(emask))}

    alg = algorithms.algorithm2 if constrained else algorithms.algorithm1
    stream, spans, prof = _make_stream(log_jsonl, log_stream_every,
                                       profile_dir, name="cohort")
    if log_jsonl:
        obs_sinks.write_manifest(
            log_jsonl + ".manifest.json",
            config={"mode": "cohort", "clients": clients,
                    "participation": participation, "rounds": rounds,
                    "batch": batch, "features": features, "classes": classes,
                    "hidden": hidden, "constrained": constrained,
                    "cost_limit": cost_limit, "driver": driver, "seed": seed},
            codec=codec_obj, topology=topo,
            extra=({"dp": privacy_lib.manifest_info(
                dp, min(1.0, participation / clients), rounds=rounds)}
                if dp is not None else None))
    wall0 = time.time()
    with prof, spans.span("run", rounds=rounds):
        result = alg(mlp.per_sample_loss, params0, data, fl, rounds,
                     jax.random.fold_in(key, 2), eval_fn=eval_fn,
                     eval_every=log_every, participation=participation,
                     driver=driver, codec=codec_obj, topology=topo,
                     obs=stream if log_jsonl else None, cohort=True, dp=dp)
    stream.close()
    for i, r in enumerate(result.history["round"]):
        line = {k: float(v[i]) for k, v in result.history.items()
                if not k.startswith("round")}
        line["round"] = int(r)
        print(" ".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in line.items()), flush=True)
    shards = topo.num_shards if topo is not None else 1
    print(f"done: {rounds} rounds, population {clients}, cohort "
          f"{participation} over {shards} shard(s), "
          f"{time.time() - wall0:.1f}s", flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="model zoo arch (required for --mode sample)")
    ap.add_argument("--mode", choices=("sample", "feature", "cohort"),
                    default="sample",
                    help="sample = horizontal FL on a zoo model (Alg 1/2); "
                         "feature = vertical FL, features split across "
                         "clients (Alg 3/4, DESIGN.md §12); cohort = "
                         "million-client horizontal FL through the "
                         "participant-only O(S) engine over a virtual "
                         "population (DESIGN.md §14)")
    ap.add_argument("--clients", type=int, default=4,
                    help="feature-mode vertical client count, or cohort-mode "
                         "population size I (e.g. 1000000 — never "
                         "materialized)")
    ap.add_argument("--participation", type=int, default=256,
                    help="cohort-mode per-round cohort size S (per-round "
                         "state and compute scale with S, not --clients)")
    ap.add_argument("--features", type=int, default=128)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--cost-limit", type=float, default=1.2,
                    help="U in min ‖ω‖² s.t. loss <= U (feature mode "
                         "--constrained)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--constrained", action="store_true")
    ap.add_argument("--driver", choices=("scan", "loop"), default="scan")
    ap.add_argument("--codec", choices=("none", "int8", "int4", "topk"),
                    default="none")
    ap.add_argument("--topk-frac", type=float, default=0.01)
    ap.add_argument("--codec-impl", choices=("ref", "pallas"), default="ref",
                    help="quantizer backend: pure-jnp ref, or the fused "
                         "Pallas quantize-dequantize kernel (TPU)")
    ap.add_argument("--topology", choices=("local", "sharded"),
                    default="local",
                    help="client execution engine (DESIGN.md §11): local = "
                         "single-device; sharded = clients-as-batch-shards "
                         "over a device mesh via shard_map + psum")
    ap.add_argument("--shards", type=int, default=None,
                    help="client-shard count for --topology sharded "
                         "(default: all host devices; must divide --batch)")
    ap.add_argument("--dp-epsilon", type=float, default=None, metavar="EPS",
                    help="enable DP on the q-uploads (DESIGN.md §15): "
                         "per-release (ε, δ) target for the analytic "
                         "Gaussian calibration; the streamed dp_epsilon "
                         "metric and the manifest report the composed "
                         "cross-round ε from the subsampled-RDP accountant")
    ap.add_argument("--dp-delta", type=float, default=1e-5, metavar="DELTA",
                    help="DP δ (with --dp-epsilon; default 1e-5)")
    ap.add_argument("--dp-clip", type=float, default=1.0, metavar="C",
                    help="DP ℓ2 clip norm of each client's mean upload "
                         "(with --dp-epsilon; default 1.0)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-jsonl", default=None, metavar="PATH",
                    help="stream round/eval/span rows to PATH as JSONL while "
                         "the scan runs (obs/ subsystem, DESIGN.md §13); a "
                         "run manifest is written to PATH.manifest.json")
    ap.add_argument("--log-every", type=int, default=1, metavar="N",
                    help="emit every N-th streamed round row (default 1)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="jax.profiler trace of the whole run into DIR "
                         "(phase-annotated; open with xprof/perfetto)")
    args = ap.parse_args()
    dp = (privacy_lib.DPConfig(clip_norm=args.dp_clip,
                               epsilon=args.dp_epsilon, delta=args.dp_delta)
          if args.dp_epsilon is not None else None)
    if args.mode == "cohort":
        cohort_train_loop(clients=args.clients,
                          participation=args.participation,
                          rounds=args.steps, batch=args.batch,
                          features=args.features, classes=args.classes,
                          hidden=args.hidden, constrained=args.constrained,
                          cost_limit=args.cost_limit,
                          topology=args.topology, codec=args.codec,
                          topk_frac=args.topk_frac,
                          codec_impl=args.codec_impl, driver=args.driver,
                          log_jsonl=args.log_jsonl,
                          log_stream_every=args.log_every,
                          profile_dir=args.profile, dp=dp)
        return
    if args.mode == "feature":
        feature_train_loop(clients=args.clients, rounds=args.steps,
                           batch=args.batch, features=args.features,
                           classes=args.classes, hidden=args.hidden,
                           n=args.n, constrained=args.constrained,
                           cost_limit=args.cost_limit,
                           topology=args.topology, codec=args.codec,
                           topk_frac=args.topk_frac,
                           codec_impl=args.codec_impl, driver=args.driver,
                           log_jsonl=args.log_jsonl,
                           log_stream_every=args.log_every,
                           profile_dir=args.profile, dp=dp)
        return
    if args.arch is None:
        ap.error("--arch is required for --mode sample")
    train_loop(args.arch, args.steps, args.batch, args.seq, smoke=args.smoke,
               constrained=args.constrained, ckpt_path=args.ckpt,
               driver=args.driver, codec=args.codec,
               topk_frac=args.topk_frac, codec_impl=args.codec_impl,
               topology=args.topology, shards=args.shards,
               log_jsonl=args.log_jsonl, log_stream_every=args.log_every,
               profile_dir=args.profile, dp=dp)


if __name__ == "__main__":
    main()

"""The four assigned input shapes + ShapeDtypeStruct input_specs builders."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    seq_len=4_096,   global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32_768,  global_batch=32,  kind="prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  seq_len=32_768,  global_batch=128, kind="decode"),
    "long_500k":   ShapeConfig("long_500k",   seq_len=524_288, global_batch=1,   kind="decode"),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is runnable; reason recorded in DESIGN/EXPERIMENTS."""
    if shape.name == "long_500k" and shape.kind == "decode":
        sub_quadratic = cfg.family in ("ssm", "hybrid") or cfg.sliding_window > 0
        if not sub_quadratic:
            return False, "full-attention arch: 500k decode requires sub-quadratic attention"
    if shape.kind in ("prefill", "decode") and cfg.family == "mlp":
        return False, "non-autoregressive classifier: no decode path"
    return True, ""


def train_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for a train_step batch."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "mlp":
        return {"features": _sds((b, cfg.d_model), jnp.float32),
                "labels_onehot": _sds((b, cfg.vocab_size), jnp.float32)}
    if cfg.family == "vlm":
        st = s - cfg.num_prefix_tokens
        return {"tokens": _sds((b, st), jnp.int32),
                "targets": _sds((b, st), jnp.int32),
                "prefix_embeddings": _sds((b, cfg.num_prefix_tokens, cfg.d_model),
                                          jnp.dtype(cfg.dtype))}
    if cfg.family == "audio":
        # speech-to-text: encoder consumes seq_len frames, decoder seq_len//4 tokens
        sd = max(1, s // 4)
        return {"frame_embeddings": _sds((b, s, cfg.d_model), jnp.dtype(cfg.dtype)),
                "tokens": _sds((b, sd), jnp.int32),
                "targets": _sds((b, sd), jnp.int32)}
    return {"tokens": _sds((b, s), jnp.int32), "targets": _sds((b, s), jnp.int32)}


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        st = s - cfg.num_prefix_tokens
        return {"tokens": _sds((b, st), jnp.int32),
                "prefix_embeddings": _sds((b, cfg.num_prefix_tokens, cfg.d_model),
                                          jnp.dtype(cfg.dtype))}
    if cfg.family == "audio":
        sd = max(1, s // 4)
        return {"frame_embeddings": _sds((b, s, cfg.d_model), jnp.dtype(cfg.dtype)),
                "tokens": _sds((b, sd), jnp.int32)}
    return {"tokens": _sds((b, s), jnp.int32)}


def decode_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(token, pos, cache) stand-ins for a one-token serve_step against a
    seq_len-deep cache/state."""
    from repro.models import get_model
    b, s = shape.global_batch, shape.seq_len
    model = get_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(cfg, b, s))
    token = _sds((b, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    return token, pos, cache


def input_specs(cfg: ModelConfig, shape_name: str):
    """Unified entry: returns (kind, specs) for the given shape."""
    shape = SHAPES[shape_name]
    ok, why = supports_shape(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape_name} skipped: {why}")
    if shape.kind == "train":
        return "train", train_specs(cfg, shape)
    if shape.kind == "prefill":
        return "prefill", prefill_specs(cfg, shape)
    return "decode", decode_specs(cfg, shape)

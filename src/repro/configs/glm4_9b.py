"""glm4-9b [dense]: RoPE, GQA [hf:THUDM/glm-4-9b]. LONG_VARIANT adds a
sliding-window attention variant (beyond-paper) enabling long_500k decode."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab_size=151_552, qkv_bias=True,
    source="hf:THUDM/glm-4-9b",
)

# beyond-paper sliding-window variant: sub-quadratic decode -> long_500k capable
LONG_VARIANT = dataclasses.replace(CONFIG, name="glm4-9b-swa", sliding_window=4096)

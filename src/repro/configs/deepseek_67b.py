"""deepseek-67b [dense]: llama-architecture, 95L [arXiv:2401.02954]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab_size=102_400, head_dim=128, tie_embeddings=False,
    source="arXiv:2401.02954",
)

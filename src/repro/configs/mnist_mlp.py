"""The paper's own application model (§V): two-layer swish network for
10-class classification over 784 features, J=128 hidden cells."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mnist-mlp", family="mlp",
    n_layers=2, d_model=784, n_heads=1, n_kv_heads=1, d_ff=128,
    vocab_size=10, dtype="float32", remat=False,
    source="paper §V / §VI (MNIST, N=60000, I=10, K=784, J=128, L=10)",
)

"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention block
[arXiv:2411.15242]. ssm_state=64; shared attn+MLP applied every 6 blocks."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32_000, ssm_state=64, ssm_heads=32, ssm_expand=2,
    shared_attn_every=6, conv_width=4, chunk_size=256,
    source="arXiv:2411.15242",
)

"""seamless-m4t-medium [audio]: encoder-decoder, multimodal [arXiv:2308.11596].
The mel-spectrogram + conv feature extractor is stubbed (precomputed frame
embeddings); this config is the transformer backbone."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=256_206, encoder_layers=12, activation="gelu",
    frontend="audio",
    source="arXiv:2308.11596",
)

"""arctic-480b [moe]: 128-expert top-2 MoE with a dense residual MLP per layer
[hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab_size=32_000, head_dim=128,
    n_experts=128, experts_per_token=2, moe_d_ff=4864, dense_residual=True,
    source="hf:Snowflake/snowflake-arctic-base",
)

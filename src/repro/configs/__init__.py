from repro.configs.base import FLConfig, ModelConfig, ShapeConfig  # noqa: F401
from repro.configs.registry import ARCHS, get_config  # noqa: F401
from repro.configs.shapes import SHAPES, input_specs, supports_shape  # noqa: F401

"""Architecture/config dataclasses shared across the framework.

Every assigned architecture instantiates :class:`ModelConfig` (full size) plus a
reduced smoke variant via :func:`ModelConfig.smoke`. Input shapes are described by
:class:`ShapeConfig` (see ``configs/shapes.py`` for the four assigned shapes).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio | mlp
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                 # per-expert hidden dim (d_ff used for dense part)
    dense_residual: bool = False      # arctic-style dense MLP in parallel with MoE
    capacity_factor: float = 1.25
    # --- architecture details ---
    activation: str = "swiglu"        # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    # --- attention variant ---
    sliding_window: int = 0           # 0 = full/causal attention
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_heads: int = 0                # number of SSM heads (mamba2/mLSTM)
    ssm_expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256             # chunked linear-attention block size
    block_pattern: Tuple[str, ...] = ()   # per-layer kinds for xlstm ("m","s") /
                                          # zamba2 handled via shared_attn_every
    shared_attn_every: int = 0        # zamba2: shared attn block after every k blocks
    # --- encoder-decoder ---
    encoder_layers: int = 0           # >0 -> enc-dec model (decoder uses n_layers)
    # --- modality frontend stub ---
    frontend: str = "none"            # none | vision | audio
    num_prefix_tokens: int = 0        # patch/frame embeddings provided precomputed
    # --- numerics / sharding policy ---
    dtype: str = "bfloat16"
    remat: bool = True
    train_sharding: str = "fsdp"      # fsdp | tp
    serve_sharding: str = "tp"
    # --- perf knobs (§Perf hillclimbing; defaults = paper-faithful baseline) ---
    attention_impl: str = "dot"       # dot | chunked (online-softmax, flash-style)
    attention_block: int = 512        # K-block size for chunked attention
    seq_shard_activations: bool = False   # Megatron-style sequence parallelism
    moe_sharding: str = "fsdp"        # fsdp | expert2d (expert x ffn-dim 2D)
    norm_impl: str = "ref"            # ref | fused (custom-VJP RMSNorm backward)
    source: str = ""                  # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def smoke(self, **overrides) -> "ModelConfig":
        """Reduced variant of the same family: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        kv = max(1, min(self.n_kv_heads, n_heads))
        small = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=d,
            n_heads=n_heads,
            n_kv_heads=kv,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            head_dim=min(self.resolved_head_dim, d // n_heads),
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=min(self.ssm_heads, 4) if self.ssm_heads else 0,
            chunk_size=32,
            encoder_layers=2 if self.encoder_layers else 0,
            num_prefix_tokens=min(self.num_prefix_tokens, 8),
            block_pattern=self.block_pattern[:2] if self.block_pattern else (),
            shared_attn_every=2 if self.shared_attn_every else 0,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            dtype="float32",
            remat=False,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


@dataclass(frozen=True)
class FLConfig:
    """Federated-learning configuration (the paper's knobs)."""
    num_clients: int = 10
    batch_size: int = 100          # B: per-client minibatch (sample-based) / global (feature-based)
    mode: str = "sample"           # sample | feature  (horizontal vs vertical FL)
    # SSCA stepsizes: rho_t = a1 / t**alpha, gamma_t = a2 / t**alpha_g  (eqs. 4/6)
    a1: float = 0.9
    a2: float = 0.5
    alpha_rho: float = 0.1
    alpha_gamma: float = 0.6
    tau: float = 0.2               # strong-convexity constant in (7)/(15)/(19)/(27)
    # regularized (32) / constrained (40) formulations
    l2_lambda: float = 1e-5
    constrained: bool = False
    cost_limit: float = 0.13       # U in (40)
    penalty_c: float = 1e5         # c in Problem 4/9

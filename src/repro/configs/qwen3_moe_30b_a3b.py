"""qwen3-moe-30b-a3b [moe]: 128 experts, top-8, per-expert ffn 768
[hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=768,
    vocab_size=151_936, head_dim=128,
    n_experts=128, experts_per_token=8, moe_d_ff=768,
    source="hf:Qwen/Qwen3-30B-A3B",
)

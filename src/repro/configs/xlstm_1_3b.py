"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks [arXiv:2405.04517]. 48 blocks as
6 groups of (7 mLSTM + 1 sLSTM); d_ff=0 (mixing blocks carry their own
up/down projections)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50_304, block_pattern=("m",) * 7 + ("s",),
    conv_width=4, chunk_size=256,
    source="arXiv:2405.04517",
)

"""Architecture registry: --arch <id> resolution."""
from repro.configs import (arctic_480b, deepseek_67b, gemma_7b, glm4_9b,
                           mnist_mlp, paligemma_3b, qwen2_5_3b,
                           qwen3_moe_30b_a3b, seamless_m4t_medium, xlstm_1_3b,
                           zamba2_1_2b)

ARCHS = {
    "paligemma-3b": paligemma_3b.CONFIG,
    "arctic-480b": arctic_480b.CONFIG,
    "seamless-m4t-medium": seamless_m4t_medium.CONFIG,
    "qwen2.5-3b": qwen2_5_3b.CONFIG,
    "gemma-7b": gemma_7b.CONFIG,
    "xlstm-1.3b": xlstm_1_3b.CONFIG,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b.CONFIG,
    "deepseek-67b": deepseek_67b.CONFIG,
    "glm4-9b": glm4_9b.CONFIG,
    "glm4-9b-swa": glm4_9b.LONG_VARIANT,     # beyond-paper long-context variant
    "zamba2-1.2b": zamba2_1_2b.CONFIG,
    "mnist-mlp": mnist_mlp.CONFIG,           # the paper's own model
}

ASSIGNED = [k for k in ARCHS if k not in ("glm4-9b-swa", "mnist-mlp")]


def get_config(name: str):
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}") from None

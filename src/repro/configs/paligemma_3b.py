"""paligemma-3b [vlm]: SigLIP vision encoder + gemma-2b LM backbone
[arXiv:2407.07726]. The ViT frontend is stubbed (precomputed patch embeddings);
this config is the language/decoder transformer that consumes them."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab_size=257_216, head_dim=256, activation="geglu",
    frontend="vision", num_prefix_tokens=256,
    source="arXiv:2407.07726 (SigLIP + gemma-2b backbone)",
)

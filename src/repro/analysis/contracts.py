"""Jaxpr contract checkers for the compiled round step.

Traces the *actual* step built by ``make_algorithm1_step`` under
``lax.scan`` — exactly what ``rounds.scan_rounds`` compiles — for the
full config matrix (dense/cohort × local/sharded × identity/int8+EF ×
dp on/off) and asserts structural properties on the closed jaxpr that
no pointwise test can see:

* **scan purity** — no ``io_callback`` / ``pure_callback`` /
  ``debug_callback`` equations anywhere in the scan body.  The obs
  callback transport keeps its single ``io_callback`` in a separate
  companion program (``MetricStream._flusher``), which is checked to
  contain *exactly one* — the registered tap — while the scan stays pure
  even with a stream attached.
* **DP-before-encode** — the DP noise draw (``erf_inv``, the only
  normal-sampling primitive in the round body) appears strictly before
  the first int8 ``convert_element_type`` of the codec encode chain, so
  EF residuals and the wire only ever see privatized uploads
  (DESIGN.md §15).  Without ``dp=`` the body must contain no normal
  draw at all.
* **collective axes** — every ``psum``/``all_gather``/… axis name is ⊆
  the active topology's mesh axes; the local topology compiles to zero
  collectives.
* **wire dtypes** — ``codec.encode`` output dtypes equal the codec's
  wire spec (int8 values + f32 scales for the quantizer, f32 for
  identity/dense), via ``jax.eval_shape``.
* **no f64** — no float64/complex128 aval anywhere in the round body.

Checkers operate on the flattened equation list (depth-first over
sub-jaxprs, which preserves topological order), so ordering assertions
hold through ``pjit``/``shard_map``/``while_loop`` nesting.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

import jax
import jax.numpy as jnp

from repro.comm.codecs import (DenseEncoded, QuantEncoded, TopKEncoded,
                               make_codec, tree_flat_dim)
from repro.core import algorithms, fed, optimizer, rounds
from repro.core.privacy import DPConfig
from repro.core.topology import LocalTopology, ShardedTopology
from repro.launch.mesh import make_client_mesh
from repro.models import mlp

_CALLBACK_PRIMS = frozenset({"io_callback", "pure_callback", "debug_callback"})
_COLLECTIVE_PRIMS = frozenset({"psum", "all_gather", "all_to_all", "ppermute",
                               "pmax", "pmin", "pmean", "reduce_scatter"})

# Tiny but structurally faithful problem: ragged-free I=16 clients so the
# 8-device CI mesh divides both the population and the S=8 cohort.
_I, _N, _P, _L, _J, _B, _S = 16, 6, 10, 3, 8, 4, 8


@dataclasses.dataclass
class ContractViolation:
    config: str
    check: str
    detail: str

    def render(self) -> str:
        return f"[{self.config}] {self.check}: {self.detail}"


@dataclasses.dataclass
class ContractReport:
    configs: list[str]
    violations: list[ContractViolation]

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> dict:
        return {"num_configs": len(self.configs),
                "configs": self.configs,
                "ok": self.ok,
                "violations": [dataclasses.asdict(v) for v in self.violations]}

    def render_text(self) -> str:
        lines = [v.render() for v in self.violations]
        lines.append(f"contracts: {len(self.configs)} config(s), "
                     f"{len(self.violations)} violation(s)")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# jaxpr plumbing
# ---------------------------------------------------------------------------


def _iter_eqns(jaxpr) -> Iterable:
    """Depth-first flatten of all equations, preserving topological order."""
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                yield from _iter_eqns(sub)


def _sub_jaxprs(val) -> Iterable:
    if hasattr(val, "eqns"):
        yield val
    elif hasattr(val, "jaxpr"):
        yield val.jaxpr
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _sub_jaxprs(v)


def find_scan_body(closed):
    """The body jaxpr of the (single) lax.scan in a traced program."""
    for eqn in _iter_eqns(closed.jaxpr):
        if eqn.primitive.name == "scan":
            return eqn.params["jaxpr"].jaxpr
    raise AssertionError("no scan equation found in traced program")


def trace_scan(step_fn, state, inputs):
    """Trace exactly what rounds.scan_rounds compiles (sans the jit)."""
    closed = jax.make_jaxpr(
        lambda s, i: jax.lax.scan(step_fn, s, i))(state, inputs)
    return closed, find_scan_body(closed)


# ---------------------------------------------------------------------------
# checkers (each returns a list of violation detail strings)
# ---------------------------------------------------------------------------


def check_scan_pure(body) -> list[str]:
    out = []
    for eqn in _iter_eqns(body):
        if eqn.primitive.name in _CALLBACK_PRIMS or "callback" in eqn.primitive.name:
            out.append(f"host-effect primitive '{eqn.primitive.name}' inside "
                       "the scan body; host taps must live in the obs "
                       "companion program, never in the round")
    return out


def check_dp_before_encode(body, dp_on: bool, int8: bool) -> list[str]:
    eqns = list(_iter_eqns(body))
    noise_idx = [i for i, e in enumerate(eqns)
                 if e.primitive.name == "erf_inv"]
    enc_idx = [i for i, e in enumerate(eqns)
               if e.primitive.name == "convert_element_type"
               and getattr(e.params.get("new_dtype"), "name", "") == "int8"]
    out = []
    if dp_on and not noise_idx:
        out.append("dp enabled but no gaussian draw (erf_inv) in the body")
    if not dp_on and noise_idx:
        out.append("gaussian draw (erf_inv) in the body without dp enabled")
    if int8 and not enc_idx:
        out.append("int8 codec active but no int8 convert_element_type "
                   "in the body")
    if dp_on and int8 and noise_idx and enc_idx:
        if min(noise_idx) >= min(enc_idx):
            out.append(
                f"DP noise (eqn {min(noise_idx)}) does not precede the codec "
                f"int8 encode (eqn {min(enc_idx)}): EF residuals/wire would "
                "see raw uploads (DESIGN.md §15 ordering)")
    return out


def check_collective_axes(body, allowed: tuple[str, ...]) -> list[str]:
    out = []
    for eqn in _iter_eqns(body):
        # versioned primitive names: psum lowered as psum2 on this jax
        base = eqn.primitive.name.rstrip("0123456789")
        if base not in _COLLECTIVE_PRIMS:
            continue
        axes = eqn.params.get("axes", eqn.params.get("axis_name", ()))
        if not isinstance(axes, (tuple, list)):
            axes = (axes,)
        names = tuple(a for a in axes if isinstance(a, str))
        bad = [a for a in names if a not in allowed]
        if bad:
            out.append(f"collective '{eqn.primitive.name}' over axes {bad} "
                       f"not declared by the active topology (mesh axes: "
                       f"{allowed or '()'})")
    return out


# wire spec: Encoded-type -> {field: dtype}; None entries are not checked
_WIRE_SPECS = {
    DenseEncoded: {"values": jnp.float32},
    QuantEncoded: {"values": jnp.int8, "scales": jnp.float32},
    TopKEncoded: {"values": jnp.float32, "indices": jnp.int32},
}


def check_wire_dtypes(codec, dim: int) -> list[str]:
    if codec is None:
        return []
    key = jax.random.PRNGKey(0)
    enc = jax.eval_shape(lambda x: codec.encode(x, key),
                         jax.ShapeDtypeStruct((dim,), jnp.float32))
    return _check_encoded(enc, type(codec).__name__)


def _check_encoded(enc, codec_name: str) -> list[str]:
    out = []
    spec = _WIRE_SPECS.get(type(enc))
    if spec is None:
        # chain codecs nest; check every Encoded-typed field
        for fname in getattr(enc, "_fields", ()):
            sub = getattr(enc, fname)
            if type(sub) in _WIRE_SPECS:
                out.extend(_check_encoded(sub, codec_name))
        return out
    for fname, want in spec.items():
        got = getattr(enc, fname).dtype
        if got != want:
            out.append(f"{codec_name} wire field '{fname}' is {got}, codec "
                       f"spec pins {jnp.dtype(want).name}")
    return out


def check_no_f64(body) -> list[str]:
    for eqn in _iter_eqns(body):
        for var in eqn.outvars:
            dtype = getattr(var.aval, "dtype", None)
            # str() handles extended dtypes (PRNG key avals) that
            # jnp.dtype() cannot interpret
            if dtype is not None and str(dtype) in ("float64", "complex128"):
                return [f"float64 aval from '{eqn.primitive.name}' in the "
                        "round body; the stack is pinned to f32"]
    return []


def check_obs_tap() -> list[str]:
    """The callback transport's companion program: exactly one io_callback."""
    from repro.obs.metrics import MetricStream

    stream = MetricStream(transport="callback")
    flush = stream._flusher(("loss_est",))
    t_vec = jnp.arange(2, dtype=jnp.int32)
    ms = {"loss_est": jnp.zeros((2,), jnp.float32)}
    closed = jax.make_jaxpr(lambda t, m: flush.__wrapped__(t, m))(t_vec, ms)
    n = sum(1 for e in _iter_eqns(closed.jaxpr)
            if e.primitive.name in _CALLBACK_PRIMS)
    stream.close()
    if n != 1:
        return [f"obs flusher program has {n} callback eqns, expected "
                "exactly 1 (the registered tap)"]
    return []


# ---------------------------------------------------------------------------
# the config matrix
# ---------------------------------------------------------------------------


def _problem(key=None):
    from repro.configs.base import FLConfig

    key = jax.random.PRNGKey(7) if key is None else key
    kd, kp = jax.random.split(key)
    feats = jax.random.normal(kd, (_I * _N, _P), jnp.float32)
    labels = jax.nn.one_hot(
        jax.random.randint(jax.random.fold_in(kd, 1), (_I * _N,), 0, _L), _L)
    data = fed.partition_samples(feats, labels, _I)
    params0 = mlp.init(kp, _P, _J, _L)
    fl = FLConfig(num_clients=_I, batch_size=_B)
    return data, params0, fl


def _topology(kind: str):
    if kind == "local":
        return LocalTopology(), ()
    topo = ShardedTopology(make_client_mesh(axis="data"))
    return topo, topo.axes


def matrix_configs():
    """(name, engine, topology, codec, dp) for the full contract matrix."""
    configs = []
    for engine in ("dense", "cohort"):
        for topo in ("local", "sharded"):
            for codec in ("identity", "int8"):
                for dp in (False, True):
                    configs.append((f"{engine}/{topo}/{codec}/"
                                    f"{'dp' if dp else 'nodp'}",
                                    engine, topo, codec, dp))
    return configs


def run_config(name: str, engine: str, topo_kind: str, codec_name: str,
               dp_on: bool, execute: bool = True) -> list[ContractViolation]:
    """Trace one matrix config and run every contract checker on it."""
    data, params0, fl = _problem()
    topo, axes = _topology(topo_kind)
    codec = make_codec(codec_name)
    dp = DPConfig(clip_norm=1.0, noise_multiplier=1.0) if dp_on else None
    cohort = engine == "cohort"
    participation = _S if cohort else None

    step = algorithms.make_algorithm1_step(
        mlp.per_sample_loss, data, fl, participation=participation,
        codec=codec, topology=topo, cohort=cohort, dp=dp)
    state = algorithms._wrap_codec_state(
        optimizer.ssca_init(params0), codec,
        lambda: algorithms._sample_ef0(params0, data.num_clients, cohort))
    inputs = rounds.make_inputs(fl, 1, 3, jax.random.PRNGKey(3))

    _, body = trace_scan(step, state, inputs)
    details: list[tuple[str, list[str]]] = [
        ("scan_pure", check_scan_pure(body)),
        ("dp_before_encode",
         check_dp_before_encode(body, dp_on, codec_name == "int8")),
        ("collective_axes", check_collective_axes(body, axes)),
        ("wire_dtypes", check_wire_dtypes(codec, tree_flat_dim(params0))),
        ("no_f64", check_no_f64(body)),
    ]
    if execute:
        # run the compiled path for real so the retrace sentinel has a
        # compilation to watch and the trace above matches an executable
        out_state, metrics = rounds.scan_rounds(step, state, inputs)
        jax.block_until_ready(metrics["loss_est"])
    return [ContractViolation(name, check, d)
            for check, ds in details for d in ds]


def run_matrix(execute: bool = True) -> ContractReport:
    configs = matrix_configs()
    violations: list[ContractViolation] = []
    for cfg in configs:
        violations.extend(run_config(*cfg[:5], execute=execute))
    violations.extend(ContractViolation("obs/callback", "obs_tap", d)
                      for d in check_obs_tap())
    return ContractReport([c[0] for c in configs] + ["obs/callback"],
                          violations)

"""FLT004 — imports/uses of deprecated shims.

``repro.core.privacy.dp_sample_round`` (replaced by the first-class
``dp=`` stage on ``fed.sample_round``, DESIGN.md §15) and
``repro.launch.feature_dist`` (replaced by ``ShardedTopology`` +
``run_feature_rounds``, DESIGN.md §10) only exist for third-party
callers.  Internal code must use the replacement APIs; the shims'
DeprecationWarning messages carry this rule code.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import Finding, Module, Project

# dotted prefix -> replacement hint
_SHIMS = {
    "repro.core.privacy.dp_sample_round":
        "fed.sample_round(..., dp=DPConfig(...)) (DESIGN.md §15)",
    "repro.launch.feature_dist":
        "core.fed.feature_round / rounds.run_feature_rounds with a Topology "
        "(DESIGN.md §10)",
}
# modules that define the shims themselves
_DEFINING = {"repro.core.privacy", "repro.launch.feature_dist"}


class DeprecatedShimRule:
    code = "FLT004"
    name = "deprecated-shim"

    def check_module(self, module: Module, project: Project) -> Iterable[Finding]:
        if module.name in _DEFINING:
            return
        path = str(module.path)
        seen: set[tuple[int, str]] = set()

        def flag(line: int, col: int, what: str, shim: str) -> Iterable[Finding]:
            if (line, shim) in seen:
                return
            seen.add((line, shim))
            yield Finding(path, line, col, self.code,
                          f"{what} '{shim}' is a deprecated shim; use "
                          f"{_SHIMS[shim]}")

        for node in ast.walk(module.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    for shim in _SHIMS:
                        if a.name == shim or a.name.startswith(shim + "."):
                            yield from flag(node.lineno, node.col_offset,
                                            "import of", shim)
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    full = f"{node.module}.{a.name}"
                    for shim in _SHIMS:
                        if full == shim or full.startswith(shim + ".") or node.module == shim:
                            yield from flag(node.lineno, node.col_offset,
                                            "import of", shim)
            elif isinstance(node, (ast.Attribute, ast.Name)):
                dotted = module.dotted(node)
                if dotted:
                    for shim in _SHIMS:
                        if dotted == shim or dotted.startswith(shim + "."):
                            yield from flag(node.lineno, node.col_offset,
                                            "use of", shim)

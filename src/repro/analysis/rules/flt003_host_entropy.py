"""FLT003 — Python-side entropy/clock use in jitted scopes.

``random.*``, ``time.*``, ``datetime.*``, ``secrets.*`` inside a
jit-reachable scope bake a single host-side draw/timestamp into the
traced program as a constant: the "randomness" is frozen at trace time
and every scanned round replays it.  Host-side orchestration (benchmark
timing, manifests) is legitimately host code and is not flagged.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import Finding, Module, Project

_HOST_ENTROPY_MODULES = {"random", "time", "datetime", "secrets"}


class HostEntropyRule:
    code = "FLT003"
    name = "host-entropy-in-jit"

    def check_module(self, module: Module, project: Project) -> Iterable[Finding]:
        path = str(module.path)
        for qualname, scope in module.scopes.items():
            if not project.is_reachable(module, qualname):
                continue
            for node in scope.own_nodes():
                if not isinstance(node, ast.Call):
                    continue
                dotted = module.dotted(node.func)
                if not dotted:
                    continue
                root = dotted.split(".")[0]
                imported = any(v == root or v.startswith(root + ".")
                               for v in module.imports.values())
                if root in _HOST_ENTROPY_MODULES and imported:
                    yield Finding(
                        path, node.lineno, node.col_offset, self.code,
                        f"host call '{dotted}' in jit-reachable scope '{qualname}' "
                        "is frozen into the trace as a constant; use jax.random "
                        "keys / traced round indices instead")

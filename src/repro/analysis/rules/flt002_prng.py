"""FLT002 — PRNG key reuse and non-stable per-client key derivation.

Three patterns:

* **Straight-line reuse** — the same key variable (same assignment
  generation) consumed by two ``jax.random`` sampler/``split`` calls
  repeats the randomness.
* **Loop reuse** — a key defined outside a loop consumed inside it
  without being reassigned in the loop body draws identical randomness
  every iteration.  ``fold_in(key, i)`` (a Call argument, not a bare
  Name) is the sanctioned pattern and is never flagged.
* **Per-client split** — ``jax.random.split(key, num_clients)`` derives
  per-client keys positionally, so dense and cohort engines disagree;
  derive from stable client ids with ``fed.client_keys`` (``fold_in``,
  DESIGN.md §14).

``fold_in`` itself is neither a consumer nor a violation: folding the
same base key with different data is exactly the recommended idiom.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import Finding, Module, Project

_SAMPLERS = {
    "normal", "uniform", "randint", "bernoulli", "bits", "permutation",
    "shuffle", "dirichlet", "choice", "categorical", "gumbel", "laplace",
    "exponential", "truncated_normal", "rademacher", "beta", "cauchy",
    "gamma", "poisson", "t", "orthogonal", "ball", "maxwell",
    "multivariate_normal", "binomial", "gengamma", "loggamma", "pareto",
    "rayleigh", "weibull_min",
}
_CONSUMERS = _SAMPLERS | {"split"}
_CLIENT_AXIS_HINTS = {"num_clients", "n_clients", "clients"}


def _is_random_call(node: ast.Call, module: Module) -> str | None:
    """Return the jax.random function name if this is a consuming call."""
    dotted = module.dotted(node.func)
    if not dotted:
        return None
    mod, _, fn = dotted.rpartition(".")
    if fn in _CONSUMERS and (mod in ("jax.random", "random") and
                             module.imports.get(mod.split(".")[0], mod.split(".")[0]).startswith("jax")
                             or mod == "jax.random"):
        return fn
    return None


class _ScopeState:
    def __init__(self) -> None:
        self.gen: dict[str, int] = {}
        # (name, gen) -> (fn, line) of first consuming use
        self.used: dict[tuple[str, int], tuple[str, int]] = {}

    def bump(self, name: str) -> None:
        self.gen[name] = self.gen.get(name, 0) + 1


class PRNGReuseRule:
    code = "FLT002"
    name = "prng-key-reuse"

    def check_module(self, module: Module, project: Project) -> Iterable[Finding]:
        path = str(module.path)
        for qualname, scope in module.scopes.items():
            node = scope.node
            body = node.body if isinstance(node.body, list) else [ast.Expr(node.body)]
            state = _ScopeState()
            findings: list[Finding] = []
            self._walk(body, module, state, findings, path, loop_depth=0,
                       loop_assigned=set())
            yield from findings

    # ------------------------------------------------------------------

    def _walk(self, stmts: list[ast.stmt], module: Module, state: _ScopeState,
              out: list[Finding], path: str, loop_depth: int,
              loop_assigned: set[str]) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                continue
            if isinstance(stmt, (ast.For, ast.While)):
                inner_assigned = _assigned_names(stmt)
                # uses first (loop header expressions), then body with loop context
                for expr in _header_exprs(stmt):
                    self._visit_expr(expr, module, state, out, path,
                                     loop_depth, loop_assigned)
                for t in _target_names(getattr(stmt, "target", None)):
                    state.bump(t)
                self._walk(stmt.body + stmt.orelse, module, state, out, path,
                           loop_depth + 1, inner_assigned)
                continue
            if isinstance(stmt, (ast.If, ast.With, ast.Try)):
                for expr in _header_exprs(stmt):
                    self._visit_expr(expr, module, state, out, path,
                                     loop_depth, loop_assigned)
                for block in _sub_blocks(stmt):
                    self._walk(block, module, state, out, path,
                               loop_depth, loop_assigned)
                for t in _with_targets(stmt):
                    state.bump(t)
                continue
            # plain statement: visit expressions (uses), then bump targets
            for child in _calls_excluding_nested(stmt):
                self._visit_call(child, module, state, out, path,
                                 loop_depth, loop_assigned)
            for t in _target_names(stmt):
                state.bump(t)

    def _visit_expr(self, expr: ast.AST, module: Module, state: _ScopeState,
                    out: list[Finding], path: str, loop_depth: int,
                    loop_assigned: set[str]) -> None:
        for child in _calls_excluding_nested(expr):
            self._visit_call(child, module, state, out, path,
                             loop_depth, loop_assigned)

    def _visit_call(self, node: ast.Call, module: Module, state: _ScopeState,
                    out: list[Finding], path: str, loop_depth: int,
                    loop_assigned: set[str]) -> None:
        fn = _is_random_call(node, module)
        if fn is None:
            return
        # per-client split: split(key, <client-count expr>)
        if fn == "split" and len(node.args) >= 2:
            for sub in ast.walk(node.args[1]):
                hint = None
                if isinstance(sub, ast.Attribute) and sub.attr in _CLIENT_AXIS_HINTS:
                    hint = sub.attr
                elif isinstance(sub, ast.Name) and sub.id in _CLIENT_AXIS_HINTS:
                    hint = sub.id
                if hint:
                    out.append(Finding(
                        path, node.lineno, node.col_offset, self.code,
                        f"per-client keys derived via jax.random.split over "
                        f"'{hint}' are positional; derive from stable client ids "
                        "with fed.client_keys (fold_in) so dense and cohort "
                        "engines draw identical randomness (DESIGN.md §14)"))
                    break
        if not node.args or not isinstance(node.args[0], ast.Name):
            return
        key_name = node.args[0].id
        gen = state.gen.get(key_name, 0)
        prev = state.used.get((key_name, gen))
        if prev is not None:
            pfn, pline = prev
            out.append(Finding(
                path, node.lineno, node.col_offset, self.code,
                f"PRNG key '{key_name}' already consumed by jax.random.{pfn} "
                f"at line {pline}; reusing it repeats the randomness — derive "
                "a fresh key with fold_in/split"))
        else:
            state.used[(key_name, gen)] = (fn, node.lineno)
        if (loop_depth > 0 and key_name not in loop_assigned
                and state.gen.get(key_name, 0) == gen and prev is None):
            out.append(Finding(
                path, node.lineno, node.col_offset, self.code,
                f"PRNG key '{key_name}' defined outside the loop is consumed "
                "by jax.random." + fn + " inside it without reassignment; every "
                "iteration repeats the same randomness — fold_in the loop index"))


def _calls_excluding_nested(node: ast.AST) -> list[ast.Call]:
    """Call nodes in evaluation order, not descending into nested scopes."""
    calls: list[ast.Call] = []
    stack: list[ast.AST] = [node]
    while stack:
        cur = stack.pop()
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)) and cur is not node:
            continue
        if isinstance(cur, ast.Call):
            calls.append(cur)
        stack.extend(ast.iter_child_nodes(cur))
    calls.sort(key=lambda c: (c.lineno, c.col_offset))
    return calls


def _assigned_names(loop: ast.stmt) -> set[str]:
    """Names (re)bound anywhere inside the loop, targets only — a bare
    Name *load* must not count as an assignment."""
    names = set(_target_names(getattr(loop, "target", None)))
    for sub in ast.walk(loop):
        if isinstance(sub, (ast.Assign, ast.AugAssign, ast.AnnAssign,
                            ast.NamedExpr)):
            names.update(_target_names(sub))
        elif isinstance(sub, ast.For):
            names.update(_target_names(sub.target))
        elif isinstance(sub, ast.With):
            names.update(_with_targets(sub))
    return names


def _target_names(node: ast.AST | None) -> set[str]:
    names: set[str] = set()
    if node is None:
        return names
    targets: list[ast.AST] = []
    if isinstance(node, ast.Assign):
        targets = list(node.targets)
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign, ast.NamedExpr)):
        targets = [node.target]
    elif isinstance(node, (ast.Name, ast.Tuple, ast.List, ast.Starred)):
        targets = [node]
    for t in targets:
        for sub in ast.walk(t):
            if isinstance(sub, ast.Name):
                names.add(sub.id)
    return names


def _header_exprs(stmt: ast.stmt) -> list[ast.AST]:
    if isinstance(stmt, ast.For):
        return [stmt.iter]
    if isinstance(stmt, (ast.While, ast.If)):
        return [stmt.test]
    if isinstance(stmt, ast.With):
        return [item.context_expr for item in stmt.items]
    return []


def _sub_blocks(stmt: ast.stmt) -> list[list[ast.stmt]]:
    if isinstance(stmt, ast.If):
        return [stmt.body, stmt.orelse]
    if isinstance(stmt, ast.With):
        return [stmt.body]
    if isinstance(stmt, ast.Try):
        blocks = [stmt.body, stmt.orelse, stmt.finalbody]
        blocks.extend(h.body for h in stmt.handlers)
        return blocks
    return []


def _with_targets(stmt: ast.stmt) -> set[str]:
    names: set[str] = set()
    if isinstance(stmt, ast.With):
        for item in stmt.items:
            names.update(_target_names(item.optional_vars))
    return names

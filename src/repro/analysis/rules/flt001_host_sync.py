"""FLT001 — host-sync ops reachable from a jitted/scanned scope.

A ``.item()`` / ``.tolist()`` / ``np.*`` / ``jax.device_get`` call, or a
``float()``/``int()``/``bool()`` of a traced value, inside a scope that
is reachable from a jit entry forces a device→host transfer at trace
time (or a concretization error), serializing the scan dispatch that
PR 5 measured at 3–4% per stray effect.  Host-side code (benchmark
timing loops, obs sinks, accountants) is *not* flagged: reachability is
computed from actual jit entries, and callback-registered functions are
host code by construction.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import Finding, Module, Project

_SYNC_METHODS = {"item", "tolist"}
_CASTS = {"float", "int", "bool", "complex"}


def _mentions_traced_value(node: ast.AST, module: Module) -> bool:
    """True if the expression contains a jax/jnp-rooted call or name."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            target = module.imports.get(sub.id, "")
            if target == "jax" or target.startswith(("jax.", "jax.numpy")):
                return True
    return False


class HostSyncRule:
    code = "FLT001"
    name = "host-sync-in-jit"

    def check_module(self, module: Module, project: Project) -> Iterable[Finding]:
        path = str(module.path)
        for qualname, scope in module.scopes.items():
            if not project.is_reachable(module, qualname):
                continue
            for node in scope.own_nodes():
                if not isinstance(node, ast.Call):
                    continue
                # x.item() / x.tolist()
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr in _SYNC_METHODS
                        and not node.args):
                    yield Finding(path, node.lineno, node.col_offset, self.code,
                                  f".{node.func.attr}() forces a device->host sync "
                                  f"inside jit-reachable scope '{qualname}'; keep the "
                                  "value traced or move the readout behind the scan")
                    continue
                dotted = module.dotted(node.func)
                if dotted is None:
                    # float(jnp.max(x)) — concretizes a tracer
                    continue
                root = dotted.split(".")[0]
                if root == "numpy" or module.imports.get(root, "") == "numpy":
                    yield Finding(path, node.lineno, node.col_offset, self.code,
                                  f"numpy call '{dotted}' inside jit-reachable scope "
                                  f"'{qualname}' materializes on host; use jnp")
                elif dotted in ("jax.device_get", "jax.block_until_ready"):
                    yield Finding(path, node.lineno, node.col_offset, self.code,
                                  f"'{dotted}' inside jit-reachable scope "
                                  f"'{qualname}' is a host sync")
                elif (dotted in _CASTS and node.args
                      and _mentions_traced_value(node.args[0], module)):
                    yield Finding(path, node.lineno, node.col_offset, self.code,
                                  f"{dotted}() of a traced value inside jit-reachable "
                                  f"scope '{qualname}' concretizes the tracer; use "
                                  "jnp casts or keep it an array")

"""FLT005 — f64 literals and silent dtype promotion in kernel/codec code.

Scoped to ``repro.kernels`` and ``repro.comm``: the wire formats and
Pallas kernels pin exact dtypes (int8 values + f32 scales, f32 topk +
int32 indices), so a ``float64`` mention or a dtype-less array
constructor (``jnp.zeros(n)`` / ``jnp.arange(n)`` default to the
x64-flag-dependent dtype) silently widens a buffer, breaks bit-equal
wire assertions across hosts, and doubles bytes-on-wire.  Host-side
high-precision math (e.g. the RDP accountant's ``np.float64``) lives
outside these prefixes and is deliberately out of scope.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import Finding, Module, Project

_STRICT_PREFIXES = ("repro.kernels", "repro.comm")
_CTORS_NEED_DTYPE = {"zeros", "ones", "full", "empty", "arange", "linspace",
                     "eye", "identity"}
_F64_NAMES = {"float64", "double", "f64", "complex128"}


class DtypePromotionRule:
    code = "FLT005"
    name = "dtype-promotion"

    def check_module(self, module: Module, project: Project) -> Iterable[Finding]:
        if not (module.name.startswith(_STRICT_PREFIXES)
                or module.scope_marker == "kernel"):
            return
        path = str(module.path)
        for node in ast.walk(module.tree):
            # float64 mentions: jnp.float64 / np.float64 / dtype="float64"
            if isinstance(node, ast.Attribute) and node.attr in _F64_NAMES:
                dotted = module.dotted(node)
                if dotted and dotted.split(".")[0] in ("jax", "numpy"):
                    yield Finding(path, node.lineno, node.col_offset, self.code,
                                  f"'{dotted}' in kernel/codec code: the stack is "
                                  "pinned to f32/int8 wire dtypes; f64 doubles "
                                  "bytes-on-wire and breaks bit-equal wire "
                                  "assertions")
            elif (isinstance(node, ast.Constant) and isinstance(node.value, str)
                  and node.value in _F64_NAMES):
                yield Finding(path, node.lineno, node.col_offset, self.code,
                              f"dtype string '{node.value}': the stack is pinned to "
                              "f32/int8 wire dtypes")
            elif isinstance(node, ast.Call):
                name = node.func.attr if isinstance(node.func, ast.Attribute) else None
                if name in _CTORS_NEED_DTYPE:
                    dotted = module.dotted(node.func)
                    if not dotted or dotted.split(".")[0] not in ("jax", "numpy"):
                        continue
                    # dtype may be the last positional arg or a keyword
                    has_dtype = any(k.arg == "dtype" for k in node.keywords)
                    npos = {"zeros": 2, "ones": 2, "full": 3, "empty": 2,
                            "eye": 2, "identity": 2}.get(name)
                    if npos is not None and len(node.args) >= npos:
                        has_dtype = True
                    if not has_dtype:
                        yield Finding(
                            path, node.lineno, node.col_offset, self.code,
                            f"'{dotted}' without an explicit dtype in kernel/codec "
                            "code silently takes the default (weak) dtype; pin it "
                            "(e.g. jnp.float32) so wire buffers stay bit-stable")

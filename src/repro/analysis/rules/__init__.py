"""Rule registry for the FLT lint pass.

Each rule is a class with a ``code``, a ``name``, and a
``check_module(module, project) -> Iterable[Finding]`` method.  To add a
rule: create ``rules/fltNNN_<slug>.py``, subclass nothing (duck-typed),
append it to ``ALL_RULES``, document it in DESIGN.md §16, and commit a
bad/clean fixture pair under ``tests/fixtures/analysis/``.
"""

from repro.analysis.rules.flt001_host_sync import HostSyncRule
from repro.analysis.rules.flt002_prng import PRNGReuseRule
from repro.analysis.rules.flt003_host_entropy import HostEntropyRule
from repro.analysis.rules.flt004_deprecated import DeprecatedShimRule
from repro.analysis.rules.flt005_dtype import DtypePromotionRule
from repro.analysis.rules.flt006_carry import CarryHygieneRule

ALL_RULES = [
    HostSyncRule,
    PRNGReuseRule,
    HostEntropyRule,
    DeprecatedShimRule,
    DtypePromotionRule,
    CarryHygieneRule,
]

RULES_BY_CODE = {r.code: r for r in ALL_RULES}

__all__ = ["ALL_RULES", "RULES_BY_CODE"]

"""FLT006 — mutable default args and non-pytree state in scan carries.

A mutable default (``def f(x, acc=[])``) is shared across calls — in a
traced context it leaks tracers between traces and poisons the jit
cache.  A ``lax.scan`` carry containing a ``set`` / generator /
comprehension-of-set is not a pytree and fails at trace time with an
opaque leaf error; flagging the init expression points at the real
culprit.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import Finding, Module, Project

_IMMUTABLE_CTOR_NAMES = {"tuple", "frozenset", "namedtuple", "partial",
                         "MappingProxyType"}
_NON_PYTREE = (ast.Set, ast.SetComp, ast.GeneratorExp)


class CarryHygieneRule:
    code = "FLT006"
    name = "carry-hygiene"

    def check_module(self, module: Module, project: Project) -> Iterable[Finding]:
        path = str(module.path)
        for node in ast.walk(module.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                args = node.args
                for default in list(args.defaults) + [d for d in args.kw_defaults if d]:
                    if isinstance(default, (ast.List, ast.Dict, ast.Set)):
                        yield Finding(
                            path, default.lineno, default.col_offset, self.code,
                            "mutable default argument is shared across calls and "
                            "leaks tracers across traces; default to None and "
                            "construct inside the function")
                    elif (isinstance(default, ast.Call)
                          and isinstance(default.func, ast.Name)
                          and default.func.id in ("list", "dict", "set")):
                        yield Finding(
                            path, default.lineno, default.col_offset, self.code,
                            f"mutable default '{default.func.id}()' is shared "
                            "across calls; default to None and construct inside "
                            "the function")
            elif isinstance(node, ast.Call):
                name = node.func.attr if isinstance(node.func, ast.Attribute) else (
                    node.func.id if isinstance(node.func, ast.Name) else None)
                if name == "scan" and len(node.args) >= 2:
                    init = node.args[1]
                    for sub in ast.walk(init):
                        if isinstance(sub, _NON_PYTREE):
                            yield Finding(
                                path, sub.lineno, sub.col_offset, self.code,
                                "scan carry init contains a set/generator, which "
                                "is not a pytree; use tuples/dicts/NamedTuples so "
                                "the carry flattens into traced leaves")
                            break

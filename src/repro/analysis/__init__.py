"""Static analysis for the federated SSCA stack.

Two layers (DESIGN.md §16):

* :mod:`repro.analysis.lint` — an AST linter (rule codes ``FLT001`` …
  ``FLT006``) over ``src/`` and ``benchmarks/`` that statically enforces
  hot-path hygiene: no host syncs or host entropy reachable from a jitted
  scope, no PRNG key reuse, no deprecated shims, no silent dtype
  promotion in kernel/codec code, no non-pytree scan carries.
* :mod:`repro.analysis.contracts` — jaxpr contract checkers that trace
  the *compiled* round step over the full config matrix (dense/cohort ×
  local/sharded × identity/int8+EF × dp on/off) and assert structural
  properties the compiler cannot: scan-body purity, DP-before-encode
  ordering, collective axes ⊆ mesh axes, wire dtypes == codec spec.
* :mod:`repro.analysis.retrace` — a recompile sentinel wrapping
  ``rounds._scan_jit`` that fails if a config traces more than once per
  process.

CLI: ``python -m repro.analysis [--format json] [paths...]``.
"""

from repro.analysis.lint import Finding, LintResult, lint_paths

__all__ = ["Finding", "LintResult", "lint_paths"]

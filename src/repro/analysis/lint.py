"""AST lint engine with project-wide jit-reachability.

The linter parses every Python file under the requested paths into a
:class:`Project`: per-module import tables, an index of every function
and lambda (keyed by dotted qualname), and a call graph.  Scopes passed
to a jit entry point (``jax.jit``, ``lax.scan``, ``vmap``, ``shard_map``,
``with_comm_carry``, ``Topology.weighted_sum`` fn-args, …) become
*roots*; reachability is the fixpoint closure of the call graph from
those roots, with host-boundary escapes (``io_callback`` /
``pure_callback`` / ``debug_callback`` / thread targets) explicitly
excluded so registered host taps are never treated as device code.

Rules (``repro.analysis.rules``) receive each module plus the project
and yield :class:`Finding`s.  Per-line suppression::

    x.item()  # flint: disable=FLT001
    anything  # flint: disable        (all rules on this line)

Reports render as text (``path:line:col CODE message``) or JSON.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Iterable, Iterator

# Final attribute names whose function-valued call arguments enter a
# traced/jitted scope.  Includes the repo's own hot-path entry points:
# ``with_comm_carry`` wraps the body it is given into the scanned step,
# ``scoped`` wraps it in a named_scope inside the scan, and the
# Topology aggregation methods vmap/shard_map their client function.
JIT_ENTRY_NAMES = frozenset({
    "jit", "pjit", "vmap", "pmap", "grad", "value_and_grad", "vjp", "jvp",
    "scan", "while_loop", "fori_loop", "cond", "switch", "associative_scan",
    "checkpoint", "remat", "custom_vjp", "custom_jvp", "shard_map",
    "make_jaxpr", "eval_shape", "pallas_call", "named_call",
    # repo-specific entries
    "with_comm_carry", "scoped", "weighted_sum", "feature_sum",
})

# Calls whose function-valued arguments run on the *host*, not in the
# traced program: passing a fn here must not mark it jit-reachable.
HOST_BOUNDARY_NAMES = frozenset({
    "io_callback", "pure_callback", "debug_callback", "callback", "Thread",
})

_SUPPRESS_RE = re.compile(r"#\s*flint:\s*disable(?:=([A-Za-z0-9_,\s]+))?")
# file-level marker (first 10 lines): `# flint: scope=kernel` opts a module
# outside repro.kernels/repro.comm into the strict kernel/codec dtype rules
_SCOPE_RE = re.compile(r"#\s*flint:\s*scope=(\w+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    code: str
    message: str
    suppressed: bool = False

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col} {self.code} {self.message}"


@dataclasses.dataclass
class Scope:
    """One function/lambda body, the unit of jit-reachability."""

    qualname: str
    node: ast.AST  # FunctionDef | AsyncFunctionDef | Lambda
    module: "Module"

    @property
    def key(self) -> tuple[str, str]:
        return (self.module.name, self.qualname)

    def own_nodes(self) -> Iterator[ast.AST]:
        """Walk this scope's body, excluding nested function/lambda bodies."""
        body = self.node.body if isinstance(self.node.body, list) else [self.node.body]
        stack: list[ast.AST] = list(body)
        while stack:
            node = stack.pop()
            yield node
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                    yield child  # the def executes here; its body is a separate scope
                    continue
                stack.append(child)


class Module:
    def __init__(self, path: Path, name: str, source: str):
        self.path = path
        self.name = name
        self.source = source
        self.tree = ast.parse(source, filename=str(path))
        self.lines = source.splitlines()
        # alias -> fully dotted target ("jnp" -> "jax.numpy",
        # "fed" -> "repro.core.fed", "sample_round" -> "repro.core.fed.sample_round")
        self.imports: dict[str, str] = {}
        self.scopes: dict[str, Scope] = {}
        # qualname of the scope lexically enclosing each scope ("" = module)
        self.scope_parent: dict[str, str] = {}
        # method name -> [qualname] for name-based virtual dispatch
        self.methods: dict[str, list[str]] = {}
        self.suppressions = self._parse_suppressions()
        self.scope_marker = next(
            (m.group(1) for line in self.lines[:10]
             if (m := _SCOPE_RE.search(line))), None)
        self._index()

    def _parse_suppressions(self) -> dict[int, frozenset[str] | None]:
        out: dict[int, frozenset[str] | None] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if m:
                codes = m.group(1)
                out[i] = frozenset(c.strip().upper() for c in codes.split(",") if c.strip()) if codes else None
        return out

    def _index(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname:
                        self.imports[a.asname] = a.name
                    else:
                        top = a.name.split(".")[0]
                        self.imports[top] = top
            elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
                for a in node.names:
                    if a.name != "*":
                        self.imports[a.asname or a.name] = f"{node.module}.{a.name}"

        def visit(node: ast.AST, prefix: str, in_class: str | None) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qn = f"{prefix}{child.name}"
                    self.scopes[qn] = Scope(qn, child, self)
                    self.scope_parent[qn] = prefix[:-1] if prefix else ""
                    if in_class:
                        self.methods.setdefault(child.name, []).append(qn)
                    visit(child, f"{qn}.", None)
                elif isinstance(child, ast.Lambda):
                    qn = f"{prefix}<lambda@{child.lineno}:{child.col_offset}>"
                    self.scopes[qn] = Scope(qn, child, self)
                    self.scope_parent[qn] = prefix[:-1] if prefix else ""
                    visit(child, f"{qn}.", None)
                elif isinstance(child, ast.ClassDef):
                    visit(child, f"{prefix}{child.name}.", child.name)
                else:
                    visit(child, prefix, in_class)

        visit(self.tree, "", None)
        # map every AST node id to its innermost enclosing scope qualname
        self.node_scope: dict[int, str] = {}
        for qn, scope in self.scopes.items():
            for n in scope.own_nodes():
                self.node_scope[id(n)] = qn

    def enclosing_scope(self, node: ast.AST) -> str:
        return self.node_scope.get(id(node), "")

    def dotted(self, node: ast.AST) -> str | None:
        """Resolve a Name/Attribute expression to a fully dotted path."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.imports.get(node.id, node.id)
        parts.append(root)
        return ".".join(reversed(parts))

    def is_suppressed(self, line: int, code: str) -> bool:
        codes = self.suppressions.get(line, False)
        if codes is False:
            return False
        return codes is None or code.upper() in codes


class Project:
    """All linted modules plus the jit-reachability fixpoint."""

    def __init__(self, files: list[Path], root: Path):
        self.root = root
        self.modules: dict[str, Module] = {}
        self.errors: list[Finding] = []
        for f in files:
            name = _module_name(f, root)
            try:
                self.modules[name] = Module(f, name, f.read_text())
            except SyntaxError as e:
                self.errors.append(Finding(str(f), e.lineno or 0, e.offset or 0,
                                           "FLT000", f"syntax error: {e.msg}"))
        self.methods: dict[str, list[tuple[str, str]]] = {}
        for mod in self.modules.values():
            for mname, qns in mod.methods.items():
                self.methods.setdefault(mname, []).extend((mod.name, q) for q in qns)
        self.reachable: set[tuple[str, str]] = set()
        self._compute_reachability()

    # -- resolution ------------------------------------------------------

    def resolve_function(self, expr: ast.AST, module: Module, scope_qn: str
                         ) -> list[tuple[str, str]]:
        """Resolve a function-valued expression to candidate scope keys."""
        if isinstance(expr, ast.Lambda):
            qn = f"<lambda@{expr.lineno}:{expr.col_offset}>"
            for cand, sc in module.scopes.items():
                if sc.node is expr:
                    return [(module.name, cand)]
            return []
        if isinstance(expr, ast.Name):
            # lexical lookup: nested defs of enclosing scopes, then module level
            chain = []
            cur = scope_qn
            while cur:
                chain.append(cur)
                cur = module.scope_parent.get(cur, "")
            for outer in chain:
                cand = f"{outer}.{expr.id}"
                if cand in module.scopes:
                    return [(module.name, cand)]
            if expr.id in module.scopes:
                return [(module.name, expr.id)]
            target = module.imports.get(expr.id)
            if target:
                mod_name, _, fn = target.rpartition(".")
                if mod_name in self.modules and fn in self.modules[mod_name].scopes:
                    return [(mod_name, fn)]
            return []
        if isinstance(expr, ast.Attribute):
            dotted = module.dotted(expr)
            if dotted:
                mod_name, _, fn = dotted.rpartition(".")
                if mod_name in self.modules and fn in self.modules[mod_name].scopes:
                    return [(mod_name, fn)]
            # virtual dispatch by method name (topo.weighted_sum, codec.encode, …)
            if expr.attr in self.methods:
                return list(self.methods[expr.attr])
        return []

    # -- reachability ----------------------------------------------------

    def _compute_reachability(self) -> None:
        roots: set[tuple[str, str]] = set()
        # edges computed lazily per reachable scope
        for mod in self.modules.values():
            for node in ast.walk(mod.tree):
                # decorator roots: @jax.jit / @partial(jax.jit, ...)
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    for dec in node.decorator_list:
                        target = dec.func if isinstance(dec, ast.Call) else dec
                        name = _final_name(target)
                        if name in JIT_ENTRY_NAMES or (
                            isinstance(dec, ast.Call)
                            and any(_final_name(a) in JIT_ENTRY_NAMES for a in dec.args)
                        ):
                            scope_qn = mod.enclosing_scope(node)
                            qn = f"{scope_qn}.{node.name}" if scope_qn else node.name
                            if qn in mod.scopes:
                                roots.add((mod.name, qn))
                if isinstance(node, ast.Call):
                    name = _final_name(node.func)
                    if name in JIT_ENTRY_NAMES:
                        scope_qn = mod.enclosing_scope(node)
                        for arg in list(node.args) + [k.value for k in node.keywords]:
                            for key in self.resolve_function(arg, mod, scope_qn):
                                roots.add(key)

        self.reachable = set(roots)
        work = list(roots)
        while work:
            mod_name, qn = work.pop()
            mod = self.modules.get(mod_name)
            if mod is None or qn not in mod.scopes:
                continue
            scope = mod.scopes[qn]
            new: set[tuple[str, str]] = set()
            for node in scope.own_nodes():
                if not isinstance(node, ast.Call):
                    continue
                name = _final_name(node.func)
                if name in HOST_BOUNDARY_NAMES:
                    continue
                new.update(self.resolve_function(node.func, mod, qn))
                # fn-valued args passed onward from a reachable scope
                # (e.g. client fn handed to topo.weighted_sum)
                for arg in list(node.args) + [k.value for k in node.keywords]:
                    new.update(self.resolve_function(arg, mod, qn))
            # nested scopes called by name resolve above; lambdas defined
            # inline in non-call positions stay unreachable, correctly
            for key in new:
                if key not in self.reachable:
                    self.reachable.add(key)
                    work.append(key)

    def is_reachable(self, module: Module, qualname: str) -> bool:
        return (module.name, qualname) in self.reachable


@dataclasses.dataclass
class LintResult:
    findings: list[Finding]
    suppressed: list[Finding]
    files_checked: int

    @property
    def exit_code(self) -> int:
        return 1 if self.findings else 0

    def to_json(self) -> str:
        return json.dumps({
            "files_checked": self.files_checked,
            "num_findings": len(self.findings),
            "num_suppressed": len(self.suppressed),
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
        }, indent=2)

    def render_text(self) -> str:
        lines = [f.render() for f in self.findings]
        lines.append(f"{len(self.findings)} finding(s), "
                     f"{len(self.suppressed)} suppressed, "
                     f"{self.files_checked} file(s) checked")
        return "\n".join(lines)


def _final_name(node: ast.AST) -> str | None:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _module_name(path: Path, root: Path) -> str:
    try:
        rel = path.resolve().relative_to(root.resolve())
    except ValueError:
        return path.stem
    parts = list(rel.with_suffix("").parts)
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) or path.stem


def discover_files(paths: Iterable[Path]) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(f for f in p.rglob("*.py") if "__pycache__" not in f.parts))
        elif p.suffix == ".py":
            files.append(p)
    return files


def lint_paths(paths: Iterable[Path], root: Path | None = None,
               rules: Iterable | None = None) -> LintResult:
    """Lint the given files/directories; returns findings + suppressions."""
    from repro.analysis.rules import ALL_RULES

    paths = [Path(p) for p in paths]
    root = Path(root) if root is not None else _find_repo_root(paths)
    files = discover_files(paths)
    project = Project(files, root)
    active_rules = list(rules) if rules is not None else [r() for r in ALL_RULES]

    findings: list[Finding] = list(project.errors)
    suppressed: list[Finding] = []
    for mod in project.modules.values():
        for rule in active_rules:
            for f in rule.check_module(mod, project):
                if mod.is_suppressed(f.line, f.code):
                    suppressed.append(dataclasses.replace(f, suppressed=True))
                else:
                    findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return LintResult(findings, suppressed, len(files))


def _find_repo_root(paths: list[Path]) -> Path:
    for p in paths:
        cur = p.resolve()
        if cur.is_file():
            cur = cur.parent
        while cur != cur.parent:
            if (cur / "pyproject.toml").exists() or (cur / ".git").exists():
                return cur
            cur = cur.parent
    return Path.cwd()

"""CLI for the static-analysis pass.

``python -m repro.analysis``             lint src/repro + benchmarks, then run
                                         the jaxpr contract matrix + retrace
                                         sentinel (full CI gate; exit != 0 on
                                         any finding or contract violation).
``python -m repro.analysis PATH...``     lint only the given files/dirs (no
                                         contract matrix — used for fixtures).
``--format json [-o FILE]``              machine-readable report.
``--no-contracts`` / ``--only-contracts``  select a single layer.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.lint import lint_paths


def _default_paths(root: Path) -> list[Path]:
    paths = [root / "src" / "repro"]
    bench = root / "benchmarks"
    if bench.is_dir():
        paths.append(bench)
    return paths


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="FLT lints + jaxpr contract checkers for the SSCA stack")
    parser.add_argument("paths", nargs="*", type=Path,
                        help="files/dirs to lint (default: src/repro + benchmarks, "
                             "plus the contract matrix)")
    parser.add_argument("--format", choices=("text", "json"), default="text")
    parser.add_argument("-o", "--output", type=Path, default=None,
                        help="write the report to FILE instead of stdout")
    parser.add_argument("--no-contracts", action="store_true",
                        help="skip the jaxpr contract matrix")
    parser.add_argument("--only-contracts", action="store_true",
                        help="run only the jaxpr contract matrix")
    parser.add_argument("--root", type=Path, default=None,
                        help="repo root (default: discovered from paths)")
    args = parser.parse_args(argv)

    explicit_paths = bool(args.paths)
    root = args.root or Path(__file__).resolve().parents[3]
    paths = args.paths or _default_paths(root)

    report: dict = {"tool": "repro.analysis", "lint": None, "contracts": None,
                    "retrace": None}
    exit_code = 0

    if not args.only_contracts:
        result = lint_paths(paths, root=root)
        report["lint"] = json.loads(result.to_json())
        exit_code = max(exit_code, result.exit_code)
        if args.format == "text":
            _emit(result.render_text(), args.output, append=False)

    run_contracts = (args.only_contracts
                     or (not explicit_paths and not args.no_contracts))
    if run_contracts:
        from repro.analysis.contracts import run_matrix
        from repro.analysis.retrace import RetraceSentinel

        with RetraceSentinel() as sentinel:
            contract_report = run_matrix()
        report["contracts"] = contract_report.to_dict()
        report["retrace"] = sentinel.report()
        exit_code = max(exit_code, 0 if contract_report.ok else 1)
        exit_code = max(exit_code, 0 if sentinel.ok else 1)
        if args.format == "text":
            _emit(contract_report.render_text(), args.output, append=True)
            _emit(sentinel.render_text(), args.output, append=True)

    if args.format == "json":
        _emit(json.dumps(report, indent=2), args.output, append=False)
    return exit_code


def _emit(text: str, output: Path | None, append: bool) -> None:
    if output is None:
        print(text)
    else:
        mode = "a" if append and output.exists() else "w"
        with open(output, mode) as fh:
            fh.write(text + "\n")


if __name__ == "__main__":
    sys.exit(main())

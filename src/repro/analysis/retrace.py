"""Recompile sentinel for the scan-compiled round drivers.

``rounds.scan_rounds`` / ``loop_rounds`` cache one jitted callable per
step-function identity; a config that triggers a *second* trace of the
same callable (shape/dtype/pytree instability across calls, a weakly
typed scalar flipping, a donated buffer changing layout) silently pays
full compile latency every run — the exact regression PR 5's telemetry
can only see after the fact.  The sentinel wraps ``rounds._scan_jit`` /
``rounds._step_jit``, records every jitted callable they hand out, and
fails if any of them reports more than ``limit`` compilations
(``jax.jit``'s ``_cache_size``) while the sentinel is active.

Usage::

    with RetraceSentinel() as sentinel:
        run_configs()
    assert sentinel.ok, sentinel.render_text()
"""

from __future__ import annotations

import dataclasses

from repro.core import rounds


@dataclasses.dataclass
class RetraceViolation:
    kind: str       # "scan" | "step"
    compiles: int
    limit: int

    def render(self) -> str:
        return (f"retrace: {self.kind}-jit compiled {self.compiles}x "
                f"(limit {self.limit}) — per-config shapes/dtypes must be "
                "stable so one config costs one compile")


class RetraceSentinel:
    """Context manager that fails if any round-driver jit retraces."""

    def __init__(self, limit: int = 1):
        self.limit = limit
        self._tracked: list[tuple[str, object]] = []
        self._orig: dict[str, object] = {}
        self.violations: list[RetraceViolation] = []

    def __enter__(self) -> "RetraceSentinel":
        self._orig = {"_scan_jit": rounds._scan_jit,
                      "_step_jit": rounds._step_jit}

        def wrap(orig, kind):
            def wrapped(step_fn):
                fn = orig(step_fn)
                if not any(f is fn for _, f in self._tracked):
                    # baseline: entries may arrive pre-compiled from earlier
                    # use of the same step closure in this process
                    self._tracked.append((kind, fn))
                return fn
            return wrapped

        rounds._scan_jit = wrap(self._orig["_scan_jit"], "scan")
        rounds._step_jit = wrap(self._orig["_step_jit"], "step")
        return self

    def __exit__(self, *exc) -> None:
        rounds._scan_jit = self._orig["_scan_jit"]
        rounds._step_jit = self._orig["_step_jit"]
        self.check()

    def check(self) -> None:
        self.violations = [
            RetraceViolation(kind, n, self.limit)
            for kind, fn in self._tracked
            if (n := _cache_size(fn)) > self.limit
        ]

    @property
    def ok(self) -> bool:
        return not self.violations

    def report(self) -> dict:
        return {"tracked": len(self._tracked),
                "limit": self.limit,
                "ok": self.ok,
                "violations": [dataclasses.asdict(v) for v in self.violations]}

    def render_text(self) -> str:
        lines = [v.render() for v in self.violations]
        lines.append(f"retrace sentinel: {len(self._tracked)} jit(s) tracked, "
                     f"{len(self.violations)} violation(s)")
        return "\n".join(lines)


def _cache_size(fn) -> int:
    size = getattr(fn, "_cache_size", None)
    return size() if callable(size) else 0

"""Minimal msgpack checkpointing for param / optimizer-state pytrees.

Arrays are stored as (dtype, shape, raw bytes); the pytree structure is
rebuilt from a parallel nested structure of dicts/lists/tuples. Scalars
(python ints/floats) pass through. NamedTuples round-trip as lists — callers
re-wrap via the `restore_as` treedef argument.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack(leaf):
    arr = np.asarray(leaf)
    return {"__nd__": True, "dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes()}


def _unpack(obj):
    # kept as NUMPY: load_checkpoint compares stored dtypes before any
    # jnp conversion (which would silently downcast f64 with x64 disabled)
    if isinstance(obj, dict) and obj.get("__nd__"):
        return np.frombuffer(obj["data"],
                             dtype=obj["dtype"]).reshape(obj["shape"])
    return obj


def save_checkpoint(path: str, tree, step: int = 0):
    leaves, treedef = jax.tree.flatten(tree)
    payload = {"step": step, "leaves": [_pack(l) for l in leaves],
               "treedef": str(treedef)}
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def load_checkpoint(path: str, like, cast: bool = False):
    """`like`: a pytree with the same structure (e.g. fresh init) — leaves are
    replaced by the stored arrays in flatten order; treedef str is verified.

    Stored dtypes must match `like` exactly unless ``cast=True``: the old
    silent ``astype`` let a float64 checkpoint load into float32 with no
    warning (and under JAX's default x64-disabled mode the downcast happened
    before any check could see it — the comparison here is numpy-side)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves, treedef = jax.tree.flatten(like)
    stored = [_unpack(o) for o in payload["leaves"]]
    if len(stored) != len(leaves):
        raise ValueError(f"checkpoint has {len(stored)} leaves, expected {len(leaves)}")
    if payload["treedef"] != str(treedef):
        raise ValueError("checkpoint treedef mismatch")
    if not cast:
        bad = [f"leaf {i}: stored {np.asarray(s).dtype} != expected "
               f"{np.asarray(l).dtype}"
               for i, (s, l) in enumerate(zip(stored, leaves))
               if np.asarray(s).dtype != np.asarray(l).dtype]
        if bad:
            raise ValueError(
                "checkpoint dtype mismatch (pass cast=True to convert "
                "explicitly): " + "; ".join(bad))
    restored = [jnp.asarray(np.asarray(s).astype(np.asarray(l).dtype)
                            .reshape(np.asarray(l).shape))
                for s, l in zip(stored, leaves)]
    return jax.tree.unflatten(treedef, restored), payload["step"]

"""While-aware HLO cost model.

XLA's built-in ``compiled.cost_analysis()`` counts a while-loop body ONCE —
with scan-over-layers models that undercounts FLOPs/bytes/collectives by ~L×.
This parser walks the post-optimization HLO text, extracts per-computation
costs, and multiplies by loop trip counts (available in the while op's
``backend_config={"known_trip_count":{"n":...}}``), propagating multipliers
through nested scans (e.g. xLSTM's time-scan inside the layer-scan).

Counted:
  flops             2·prod(out)·prod(contracted) per dot (incl. inside fusions)
  bytes             operand+output bytes of top-level instructions (fusion
                    internals excluded — they live in registers/VMEM)
  collective bytes  output bytes per collective kind

This is the cost source for §Roofline; tests validate it against XLA's own
cost_analysis on loop-free (unrolled) modules.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
# type is either a tuple "(...)" (no nested parens; may contain /*index=N*/
# comments) or a plain array type "f32[1,2]{1,0}"
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^()]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[0-9,:TSD()]*\})?))\s+"
    r"([\w\-]+)\((.*)$")
_CALLS = re.compile(r"calls=%([\w.\-]+)")
_BODY = re.compile(r"body=%([\w.\-]+)")
_COND = re.compile(r"condition=%([\w.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND = re.compile(r"%([\w.\-]+)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops whose "bytes accessed" we do not charge (metadata/aliasing/no real traffic)
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
               "after-all", "add-dependency", "iota", "partition-id", "replica-id"}


def _shape_dims(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None, ()
    dt, dims = m.group(1), m.group(2)
    return dt, (tuple(int(d) for d in dims.split(",")) if dims else ())


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * nb
    return total


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str          # operand list + attributes (tail of line)


@dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr] = field(default_factory=list)
    defs: Dict[str, str] = field(default_factory=dict)   # name -> shape str


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line)
        if m:
            cur = Computation(name=m.group(2), is_entry=bool(m.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        mi = _INSTR.match(line)
        if mi:
            ins = Instr(name=mi.group(1), shape=mi.group(2), op=mi.group(3),
                        rest=mi.group(4))
            cur.instrs.append(ins)
            cur.defs[ins.name] = ins.shape
    return comps


def _dot_flops(ins: Instr, defs: Dict[str, str]) -> float:
    out_bytes_dims = _shape_dims(ins.shape)[1]
    out_elems = 1
    for d in out_bytes_dims:
        out_elems *= d
    cd = _LHS_CDIMS.search(ins.rest)
    contracted = 1
    if cd:
        idxs = [int(x) for x in cd.group(1).split(",") if x]
        ops = _OPERAND.findall(ins.rest)
        if ops:
            lhs_shape = defs.get(ops[0], "")
            dims = _shape_dims(lhs_shape)[1]
            for i in idxs:
                if i < len(dims):
                    contracted *= dims[i]
    return 2.0 * out_elems * contracted


def _conv_flops(ins: Instr, defs: Dict[str, str]) -> float:
    # flops ~= 2 * prod(out) * kernel_elems_per_output; approximate via rhs size
    out_dims = _shape_dims(ins.shape)[1]
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    ops = _OPERAND.findall(ins.rest)
    k_elems = 1
    if len(ops) >= 2:
        kdims = _shape_dims(defs.get(ops[1], ""))[1]
        for d in kdims:
            k_elems *= d
        odims = _shape_dims(ins.shape)[1]
        if odims:
            k_elems = max(1, k_elems // max(1, odims[-1]))  # per-output-channel
    return 2.0 * out_elems * k_elems


_SLICE_READS_OUTPUT = {"dynamic-slice", "slice", "gather"}


def _operands(ins: Instr):
    paren = ins.rest.split(")", 1)[0]
    return _OPERAND.findall(paren)


def _fusion_traffic(comp: Computation) -> float:
    """HBM traffic of a fused computation: root output + per-parameter read
    bytes. Slice-aware (a param only consumed through (dynamic-)slices is
    charged the sliced bytes) and DUS-aware (a dynamic-update-slice root
    aliases its base buffer in place: charge the update region, not the whole
    buffer — scan checkpoint stacks otherwise overcount by the trip count)."""
    if not comp.instrs:
        return 0.0
    root = comp.instrs[-1]
    params = {i.name: i.shape for i in comp.instrs if i.op == "parameter"}
    defs = comp.defs
    dus_bases = set()
    out = _shape_bytes(root.shape)
    if root.op == "dynamic-update-slice":
        ops = _operands(root)
        if ops:
            dus_bases.add(ops[0])
            upd = _shape_bytes(defs.get(ops[1], "")) if len(ops) > 1 else out
            out = upd                                 # in-place: write region only
    read = {p: 0.0 for p in params}
    full = {p: False for p in params}
    for ins in comp.instrs:
        if ins.op == "parameter":
            continue
        for j, opn in enumerate(_operands(ins)):
            if opn not in params:
                continue
            if ins.op in _SLICE_READS_OUTPUT:
                read[opn] += _shape_bytes(ins.shape)
            elif ins.op == "dynamic-update-slice" and j == 0:
                pass                                  # aliased base buffer
            else:
                full[opn] = True
    total = out
    for p, shp in params.items():
        total += _shape_bytes(shp) if full[p] else min(read[p], _shape_bytes(shp))
    return total


def _instr_bytes(ins: Instr, defs: Dict[str, str], comps, fusion_traffic) -> float:
    if ins.op in _SKIP_BYTES or ins.op.endswith("-done"):
        return 0.0
    if ins.op == "fusion":
        called = _CALLS.findall(ins.rest)
        if called and called[0] in fusion_traffic:
            return fusion_traffic[called[0]]
    out = _shape_bytes(ins.shape)
    if ins.op in _SLICE_READS_OUTPUT:
        return 2.0 * out
    if ins.op == "dynamic-update-slice":
        ops = _operands(ins)
        upd = _shape_bytes(defs.get(ops[1], "")) if len(ops) > 1 else out
        return 2.0 * upd               # read update + write update (in-place base)
    if ins.op == "scatter":
        ops = _operands(ins)
        upd = _shape_bytes(defs.get(ops[-1], "")) if ops else out
        return 2.0 * upd + out
    b = out
    for opn in _operands(ins):
        b += _shape_bytes(defs.get(opn, ""))
    return b


def xla_cost_analysis(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions.

    Older jax returns a one-element list of per-device dicts, newer jax a
    plain dict; keys like "flops"/"bytes accessed" have also drifted between
    releases. Returns a (possibly empty) dict — callers must .get() keys and
    fall back gracefully when one is absent.
    """
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def analyze(hlo: str) -> dict:
    comps = parse_computations(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # fusion-internal traffic (slice-aware)
    fusion_traffic = {c.name: _fusion_traffic(c) for c in comps.values()
                      if not c.is_entry}

    # ---- local (single-execution) cost of each computation ----
    local = {}
    for c in comps.values():
        flops = 0.0
        bts = 0.0
        coll = defaultdict(float)
        for ins in c.instrs:
            if ins.op == "dot":
                flops += _dot_flops(ins, c.defs)
            elif ins.op == "convolution":
                flops += _conv_flops(ins, c.defs)
            base = ins.op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not ins.op.endswith("-done"):
                coll[base] += _shape_bytes(ins.shape)
            bts += _instr_bytes(ins, c.defs, comps, fusion_traffic)
        local[c.name] = {"flops": flops, "bytes": bts, "coll": dict(coll)}

    # ---- call-graph multipliers ----
    mult = defaultdict(float)
    mult[entry.name] = 1.0
    work = [entry.name]
    seen_edges = set()
    fusion_like = set()
    while work:
        cname = work.pop()
        m = mult[cname]
        c = comps.get(cname)
        if c is None:
            continue
        for ins in c.instrs:
            children = []
            trip = 1.0
            if ins.op == "while":
                tb = _TRIP.search(ins.rest)
                trip = float(tb.group(1)) if tb else 1.0
                children += _BODY.findall(ins.rest) + _COND.findall(ins.rest)
            elif ins.op == "fusion" or ins.op in ("call", "custom-call", "map"):
                ch = _CALLS.findall(ins.rest) + _TO_APPLY.findall(ins.rest)
                children += ch
                fusion_like.update(ch)
            elif ins.op == "conditional":
                br = _BRANCHES.search(ins.rest)
                if br:
                    children += [x.strip().lstrip("%") for x in br.group(1).split(",")]
                children += _TO_APPLY.findall(ins.rest)
                fusion_like.update(children)
            elif ins.op in ("reduce", "reduce-window", "scatter", "sort",
                            "select-and-scatter", "all-reduce", "reduce-scatter"):
                # tiny scalar to_apply computations — ignore
                continue
            for ch in children:
                edge = (cname, ch, ins.name)
                if edge in seen_edges:
                    continue
                seen_edges.add(edge)
                mult[ch] += m * trip
                work.append(ch)

    # ---- totals ----
    # bytes: only "top-level" computations (entry, while bodies/conds,
    # conditional branches) — i.e. everything except fusion-internal comps.
    tot_flops = 0.0
    tot_bytes = 0.0
    tot_coll = defaultdict(float)
    for cname, m in mult.items():
        if m == 0.0 or cname not in local:
            continue
        lc = local[cname]
        tot_flops += m * lc["flops"]
        if cname not in fusion_like:
            tot_bytes += m * lc["bytes"]
        for k, v in lc["coll"].items():
            tot_coll[k] += m * v
    tot_coll["total"] = sum(tot_coll[k] for k in _COLLECTIVES if k in tot_coll)
    return {"flops": tot_flops, "bytes": tot_bytes,
            "collectives": dict(tot_coll), "multipliers": dict(mult)}

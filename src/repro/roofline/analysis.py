"""Roofline terms from compiled dry-run artifacts (no real hardware).

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / link_bw       (per chip)

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes (the post-SPMD module is
per-device, so these are per-chip numbers). collective_bytes is parsed from
the HLO text: the summed output sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops (a wire-bytes upper bound
of ~(n-1)/n tightness; consistent across the whole table so deltas are
meaningful).

Hardware constants (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict

import numpy as np


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12      # bf16 per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    link_bw: float = 50e9           # bytes/s per ICI link


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "bf16[16,2048,128]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        nbytes = _DTYPE_BYTES.get(dt)
        if nbytes is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * nbytes
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum of output bytes per collective kind (per device). ``-start`` ops are
    counted, matching ``-done`` pairs are not double counted."""
    out = {k: 0 for k in _COLLECTIVES}
    seen_done = set()
    for m in _OP_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        line = m.group(0)
        if "-done(" in line:
            continue  # bytes counted at the -start op
        out[kind] += _shape_bytes(shapes)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(cost: dict, coll_bytes: int, hw: HW = HW()) -> dict:
    flops = float(cost.get("flops", 0) or 0)
    # cost_analysis exposes bytes accessed as "bytes accessed"
    bts = float(cost.get("bytes accessed", 0) or 0)
    terms = {
        "flops": flops,
        "bytes": bts,
        "collective_bytes": float(coll_bytes),
        "compute_s": flops / hw.peak_flops,
        "memory_s": bts / hw.hbm_bw,
        "collective_s": float(coll_bytes) / hw.link_bw,
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["bottleneck"] = dom.replace("_s", "")
    denom = max(terms[dom], 1e-30)
    terms["bound_s"] = terms[dom]
    return terms


def jit_cost_summary(fn, *args) -> dict:
    """Compile ``fn(*args)`` and summarize its per-dispatch HLO cost.

    Returns ``{"xla": {...}, "flops": ..., "bytes": ..., "collectives": ...}``
    — the XLA ``cost_analysis()`` dict (normalized across jax versions by
    `hlo_cost.xla_cost_analysis`) alongside this package's own HLO-text
    analysis. Every stage is guarded: a backend that can't lower or analyze
    simply drops keys rather than raising, so the obs run-manifest probe
    (launch/train.py) is safe on any platform."""
    import jax

    from repro.roofline import hlo_cost

    out: dict = {}
    try:
        compiled = jax.jit(fn).lower(*args).compile()
    except Exception:
        return out
    xla = hlo_cost.xla_cost_analysis(compiled)
    if xla:
        out["xla"] = xla
    try:
        parsed = hlo_cost.analyze(compiled.as_text())
        out.update({k: parsed[k] for k in ("flops", "bytes", "collectives")
                    if k in parsed})
    except Exception:
        pass
    return out


def model_flops(cfg, num_tokens: int, param_count: int,
                active_param_count: int | None = None) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE)."""
    n = active_param_count if active_param_count is not None else param_count
    return 6.0 * n * num_tokens


def count_params(tree) -> int:
    import jax
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


def active_params(cfg, tree) -> int:
    """Active-per-token parameter count: MoE expert tensors scaled by k/E."""
    import jax
    if not getattr(cfg, "n_experts", 0):
        return count_params(tree)
    frac = cfg.experts_per_token / cfg.n_experts
    total = 0
    flat = jax.tree.flatten_with_path(tree)[0] if hasattr(jax.tree, "flatten_with_path") \
        else jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        pstr = "/".join(str(p) for p in path)
        n = int(np.prod(leaf.shape))
        if "moe" in pstr and any(w in pstr for w in ("wi", "wg", "wo")) \
                and "dense" not in pstr:
            total += int(n * frac)
        else:
            total += n
    return total

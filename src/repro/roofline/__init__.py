from repro.roofline.analysis import (HW, collective_bytes_from_hlo,  # noqa: F401
                                     model_flops, roofline_terms)

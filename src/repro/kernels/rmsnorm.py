"""Fused RMSNorm Pallas TPU kernel.

Tiling: rows are blocked (BLOCK_ROWS at a time) with the full feature dim D in
VMEM — D is at most 8192 in the zoo, so a (256, 8192) fp32 tile is 8 MiB,
comfortably inside the ~16 MiB v5e VMEM budget together with the output tile.
The reduction (mean of squares) and the (1+scale) multiply run in fp32 on the
VPU; a single HBM read and write per element (vs 3 reads for the unfused
mean/rsqrt/mul chain).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_ROWS = 256


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                     # (R, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    y = y * (1.0 + scale_ref[...].astype(jnp.float32))[None, :]
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(x, scale, eps: float = 1e-6,
                   block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = False):
    """x: (..., D); scale: (D,)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)

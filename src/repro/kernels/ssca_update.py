"""Fused SSCA update Pallas TPU kernel — the paper's Algorithm 1/3 example
update chain (eqs. (9) + (10) + (5), λ‖ω‖² folded) in one VMEM pass:

    buf' = (1-ρ)·buf + ρ·(grad + (2λ-2τ)·w)
    w'   = (1-γ)·w + γ·(-buf'/(2τ))

This is the memory-bound hot loop of SSCA training (like a fused optimizer
kernel): naive op-by-op XLA execution reads w three times and buf twice and
materializes ω̄; the fused kernel does exactly 3 HBM reads (w, buf, grad) and
2 writes (w', buf') per element. Params/buffers are flattened to 1-D and
blocked; the last block is padded (update math is elementwise, so padding
lanes are harmless and sliced away).

Scalars (ρ, γ) vary per round -> passed via scalar prefetch (SMEM) so the
kernel is compiled once, not once per round.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLOCK = 1 << 16     # 64k elems: 3 fp32 in + 2 out tiles = 1.25 MiB VMEM


def _ssca_kernel(sc_ref, w_ref, buf_ref, g_ref, wo_ref, bo_ref, *,
                 tau: float, lam: float):
    rho = sc_ref[0]
    gamma = sc_ref[1]
    w = w_ref[...].astype(jnp.float32)
    buf = buf_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    new_buf = (1.0 - rho) * buf + rho * (g + (2.0 * lam - 2.0 * tau) * w)
    new_w = (1.0 - gamma) * w + gamma * (-new_buf / (2.0 * tau))
    bo_ref[...] = new_buf
    wo_ref[...] = new_w.astype(wo_ref.dtype)


def ssca_update_pallas(w, buf, grad, rho, gamma, tau: float, lam: float,
                       block: int = DEFAULT_BLOCK, interpret: bool = False):
    """w: any shape; buf: fp32 same shape; grad: same shape. rho/gamma scalars.
    Returns (new_w, new_buf)."""
    shape = w.shape
    n = w.size
    blk = min(block, max(n, 1))
    pad = (-n) % blk
    wf = jnp.pad(w.reshape(-1), (0, pad))
    bf = jnp.pad(buf.reshape(-1).astype(jnp.float32), (0, pad))
    gf = jnp.pad(grad.reshape(-1), (0, pad))
    scalars = jnp.stack([jnp.asarray(rho, jnp.float32),
                         jnp.asarray(gamma, jnp.float32)])
    grid = (wf.size // blk,)
    new_w, new_buf = pl.pallas_call(
        functools.partial(_ssca_kernel, tau=tau, lam=lam),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((blk,), lambda i, sc: (i,)),
                      pl.BlockSpec((blk,), lambda i, sc: (i,)),
                      pl.BlockSpec((blk,), lambda i, sc: (i,))],
            out_specs=[pl.BlockSpec((blk,), lambda i, sc: (i,)),
                       pl.BlockSpec((blk,), lambda i, sc: (i,))],
        ),
        out_shape=[jax.ShapeDtypeStruct(wf.shape, w.dtype),
                   jax.ShapeDtypeStruct(bf.shape, jnp.float32)],
        interpret=interpret,
    )(scalars, wf, bf, gf)
    if pad:
        new_w, new_buf = new_w[:n], new_buf[:n]
    return new_w.reshape(shape), new_buf.reshape(shape)

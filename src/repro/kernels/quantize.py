"""Fused stochastic quantize-dequantize Pallas TPU kernel (DESIGN.md §10).

One VMEM pass per block computes, for each `chunk`-sized slice of the flat
upload vector: the absmax scale, the stochastically-rounded int levels, and
the dequantized reconstruction the error-feedback update needs:

    scale_c = max|x_c| / qmax
    v_c     = clip(floor(x_c/scale_c + u), -qmax, qmax)      u ~ U[0,1)
    xhat_c  = v_c · scale_c

Op-by-op XLA reads x once for the per-chunk max, again for the rounding,
and the int values again for the dequantize; the fused kernel reads x (and
the random bits) once and writes v/scales/xhat in the same pass — this is
the encode hot path of every compressed round (codecs.StochasticQuantizer
``impl="pallas"``).

Blocking follows kernels/ssca_update.py: the vector is reshaped to
(C, chunk) rows and blocked by `block_rows`; the padded tail rows are
all-zero (scale 0) and sliced away. Randomness comes either from a raw
uint32 `bits` operand — the portable path, bit-identical to the codecs.py
ref math and testable in interpret mode — or, with `bits=None`, from the
on-core PRNG seeded per block via scalar prefetch (TPU-only: interpret mode
has no prng_seed lowering).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.comm.codecs import uniform_from_bits

DEFAULT_BLOCK_ROWS = 128    # 128 rows x 256 lanes x (4+4+4+1)B ~ 0.4 MiB VMEM


def _qdq_kernel(sc_ref, x_ref, *rest, qmax: int, device_prng: bool):
    if device_prng:
        v_ref, s_ref, xh_ref = rest
        # multi-operand seed: (round seed, block) pairs never collide, unlike
        # seed + program_id where round t block b+1 == round t+1 block b
        pltpu.prng_seed(sc_ref[0], pl.program_id(0))
        bits = pltpu.bitcast(pltpu.prng_random_bits(x_ref.shape), jnp.uint32)
    else:
        bits_ref, v_ref, s_ref, xh_ref = rest
        bits = bits_ref[...]
    x = x_ref[...].astype(jnp.float32)
    u = uniform_from_bits(bits)     # single-sourced: codec ref == kernel
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    # explicit reciprocal-multiply, matching codecs.stochastic_round_chunks
    # exactly (XLA strength-reduces /const inconsistently across contexts)
    scale = absmax * jnp.float32(1.0 / qmax)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.floor(x / safe + u), -qmax, qmax)
    v_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale[:, 0]
    xh_ref[...] = q * scale


def stochastic_quantize_pallas(x, qmax: int, chunk: int = 256, *,
                               bits=None, seed=None,
                               block_rows: int = DEFAULT_BLOCK_ROWS,
                               interpret: bool = False):
    """x: any shape, flattened to (P,). Returns
    (values int8 (C·chunk,), scales fp32 (C,), xhat fp32 (P,)), C=ceil(P/chunk).

    bits: uint32 (C·chunk,) random bits (portable / interpret-testable);
    bits=None seeds the on-core PRNG from `seed` instead (TPU only) — the
    caller must then thread a fresh per-round seed, or every round reuses
    the same rounding noise and unbiased averaging breaks.
    """
    if bits is None and seed is None:
        raise ValueError("pass `bits` or a per-round `seed`: a fixed "
                         "device-PRNG seed repeats the rounding noise "
                         "every round")
    xf = x.reshape(-1).astype(jnp.float32)
    p = xf.shape[0]
    num_chunks = -(-p // chunk)
    rows = min(block_rows, num_chunks)
    padded_rows = -(-num_chunks // rows) * rows
    xc = jnp.pad(xf, (0, padded_rows * chunk - p)).reshape(padded_rows, chunk)

    device_prng = bits is None
    scalars = jnp.asarray([seed if device_prng else 0], jnp.int32)
    operands = [xc]
    in_specs = [pl.BlockSpec((rows, chunk), lambda i, sc: (i, 0))]
    if not device_prng:
        bc = jnp.pad(bits.reshape(-1), (0, padded_rows * chunk - bits.size))
        operands.append(bc.reshape(padded_rows, chunk))
        in_specs.append(pl.BlockSpec((rows, chunk), lambda i, sc: (i, 0)))

    v, s, xh = pl.pallas_call(
        functools.partial(_qdq_kernel, qmax=qmax, device_prng=device_prng),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(padded_rows // rows,),
            in_specs=in_specs,
            out_specs=[pl.BlockSpec((rows, chunk), lambda i, sc: (i, 0)),
                       pl.BlockSpec((rows,), lambda i, sc: (i,)),
                       pl.BlockSpec((rows, chunk), lambda i, sc: (i, 0))],
        ),
        out_shape=[jax.ShapeDtypeStruct((padded_rows, chunk), jnp.int8),
                   jax.ShapeDtypeStruct((padded_rows,), jnp.float32),
                   jax.ShapeDtypeStruct((padded_rows, chunk), jnp.float32)],
        interpret=interpret,
    )(scalars, *operands)
    return (v.reshape(-1)[: num_chunks * chunk], s[:num_chunks],
            xh.reshape(-1)[:p])

"""Jit'd public wrappers for the Pallas kernels with ref fallback.

On the TPU target the Pallas path compiles natively; in this CPU container
kernels execute via ``interpret=True`` (Python emulation of the kernel body),
which is what the per-kernel allclose tests sweep. ``use_pallas(False)`` (or
running on a CPU backend without interpret) falls back to the jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssca_update import ssca_update_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("eps", "impl"))
def rmsnorm(x, scale, eps: float = 1e-6, impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.rmsnorm_ref(x, scale, eps)
    return rmsnorm_pallas(x, scale, eps, interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("tau", "lam", "impl"))
def ssca_update(w, buf, grad, rho, gamma, tau: float, lam: float,
                impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.ssca_update_ref(w, buf, grad, rho, gamma, tau, lam)
    return ssca_update_pallas(w, buf, grad, rho, gamma, tau, lam,
                              interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=(impl == "interpret"))

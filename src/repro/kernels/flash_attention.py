"""Flash attention Pallas TPU kernel (online softmax), with causal, sliding-
window, and GQA support — the compute hot-spot of every assigned transformer.

TPU adaptation (vs the CUDA flash-attention formulation):
  - Tiling is BlockSpec-driven: Q tiles (BQ, D) stay resident in VMEM while
    K/V tiles (BK, D) stream through; the running (m, l, acc) state lives in
    VMEM scratch that persists across the innermost ("arbitrary") grid dim —
    there is no warp-level shuffle; the MXU consumes (BQ x D) @ (D x BK)
    tiles directly, so BQ/BK/D are kept multiples of 128 where possible.
  - Sliding-window + causal masking prunes whole K/V tiles via pl.when on the
    grid index, so the compiled FLOPs scale with the *visible* window.

Layouts: q (B, H, Sq, D); k, v (B, KV, Sk, D); out (B, H, Sq, D).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  scale: float, causal: bool, window: int, sq: int, sk: int,
                  bq: int, bk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    # global row/col positions of this tile (q right-aligned when sq < sk)
    offs = sk - sq
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + offs
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    run = True
    if causal:
        run = (ik * bk) <= (iq * bq + offs + bq - 1)          # tile not fully future
    if window:
        run = jnp.logical_and(run, (iq * bq + offs) - (ik * bk + bk - 1) < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                   # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)                   # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_sc[...]
        l_prev = l_sc[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_sc[...] = acc_sc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_sc[...] = m_new
        l_sc[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_sc[...]
        out = acc_sc[...] / jnp.where(l == 0.0, 1.0, l)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           scale=None, block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    assert h % kvh == 0, "GQA requires H % KV == 0"
    rep = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, "seq lens must divide block sizes"
    nq, nk = sq // bq, sk // bk
    grid = (b, h, nq, nk)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        sq=sq, sk=sk, bq=bq, bk=bk, nk=nk)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, iq, ik: (ib, ih // rep, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, iq, ik: (ib, ih // rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max m
            pltpu.VMEM((bq,), jnp.float32),      # running denom l
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
        **kwargs,
    )(q, k, v)

"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    """x: (..., D); scale: (D,). Gemma-style (1+scale) RMSNorm, fp32 internals."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def ssca_update_ref(w, buf, grad, rho, gamma, tau, lam):
    """The fused Algorithm-1-example update chain (eqs. (9)+(10)+(5), λ folded):

        buf' = (1-ρ)·buf + ρ·(grad + (2λ-2τ)·w)
        ω̄   = -buf'/(2τ)
        w'   = (1-γ)·w + γ·ω̄

    All accumulation in fp32; w' cast back to w.dtype.
    """
    w32 = w.astype(jnp.float32)
    buf32 = buf.astype(jnp.float32)
    g32 = grad.astype(jnp.float32)
    new_buf = (1.0 - rho) * buf32 + rho * (g32 + (2.0 * lam - 2.0 * tau) * w32)
    wbar = -new_buf / (2.0 * tau)
    new_w = (1.0 - gamma) * w32 + gamma * wbar
    return new_w.astype(w.dtype), new_buf


def stochastic_quantize_ref(x, bits, qmax: int, chunk: int = 256):
    """Oracle for the fused quantize-dequantize kernel: per-chunk absmax
    scales + stochastic rounding from raw uint32 bits. Delegates to the same
    comm/codecs.py math the codec ref path uses, so codec == kernel exactly.

    x: (P,); bits: uint32, (ceil(P/chunk)·chunk,).
    Returns (values int8 (C·chunk,), scales fp32 (C,), xhat fp32 (P,)).
    """
    from repro.comm.codecs import (chunk_pad, stochastic_round_chunks,
                                   uniform_from_bits)
    p = x.shape[0]
    xc = chunk_pad(x, chunk)
    u = uniform_from_bits(bits.reshape(xc.shape))
    q, scales = stochastic_round_chunks(xc, u, qmax)
    xhat = (q.astype(jnp.float32) * scales[:, None]).reshape(-1)[:p]
    return q.reshape(-1), scales, xhat


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q: (B,H,Sq,D); k,v: (B,KV,Sk,D); GQA via H % KV == 0. fp32 softmax."""
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    rep = h // kvh
    qg = q.reshape(b, kvh, rep, sq, d)
    logits = jnp.einsum("bkrqd,bksd->bkrqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq, dtype=jnp.int32)[:, None]
    kpos = jnp.arange(sk, dtype=jnp.int32)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos + (sk - sq)        # right-aligned when sq < sk
    if window:
        mask &= (qpos + (sk - sq)) - kpos < window
    logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrqs,bksd->bkrqd", w, v.astype(jnp.float32))
    return out.reshape(b, h, sq, d).astype(q.dtype)

"""Generate EXPERIMENTS.md tables from results/dryrun/*.json."""
import glob
import json
import sys

ORDER = ["paligemma-3b", "arctic-480b", "seamless-m4t-medium", "qwen2.5-3b",
         "gemma-7b", "xlstm-1.3b", "qwen3-moe-30b-a3b", "deepseek-67b",
         "glm4-9b", "glm4-9b-swa", "zamba2-1.2b", "mnist-mlp"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(path):
    r = json.load(open(path))
    return r[0] if isinstance(r, list) else r


def table(mesh):
    rows = []
    rows.append("| arch | shape | status | bottleneck | compute | memory | "
                "collective | useful | peak GB/dev |")
    rows.append("|---|---|---|---|---|---|---|---|---|")
    for a in ORDER:
        for s in SHAPES:
            try:
                r = load(f"results/dryrun/{a}_{s}_{mesh}.json")
            except FileNotFoundError:
                continue
            if r["status"] == "skipped":
                rows.append(f"| {a} | {s} | skip | — ({r['why'][:42]}) | | | | | |")
                continue
            if r["status"] != "ok":
                rows.append(f"| {a} | {s} | **{r['status']}** | "
                            f"{str(r.get('error',''))[:40]} | | | | | |")
                continue
            mem = r.get("memory") or {}
            peak = (mem.get("peak_bytes") or 0) / 1e9
            rows.append(
                f"| {a} | {s} | ok | **{r['bottleneck']}** "
                f"| {r['compute_s']*1e3:.0f} ms | {r['memory_s']*1e3:.0f} ms "
                f"| {r['collective_s']*1e3:.0f} ms "
                f"| {r.get('useful_flop_ratio', 0):.2f} | {peak:.1f} |")
    return "\n".join(rows)


def perf_row(name, base_path, var_path, hypothesis):
    b, v = load(base_path), load(var_path)
    bb = max(b["compute_s"], b["memory_s"], b["collective_s"])
    vb = max(v["compute_s"], v["memory_s"], v["collective_s"])
    return (name, b, v, bb, vb)


if __name__ == "__main__":
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    print(table(mesh))

"""Overhead + exactness claim-check for the obs/ streaming tap (DESIGN.md §13).

Claim: an ACTIVE MetricStream at log_every=1 — every round of a K=200
scan-compiled Algorithm-1 run streamed to a JSONL sink — costs < 5% in
rounds/second versus the bare ``obs=None`` scan engine, and the streamed
rows carry exactly the stacked (K,) metric values (same float32 cast, same
order). Both numbers are recorded in BENCH_obs.json.

The tap's design keeps this cheap: the compute program stays effect-free
(same cached jitted scan as the bare engine, dispatched in flush-chunks)
and each chunk's still-in-flight metric arrays go straight to a drainer
thread that blocks on them off the dispatch path (src/repro/obs/
metrics.py). The alternative io_callback transport is timed too — it is
consistently slower (any effect in a program drops it off the runtime's
fast dispatch path), which is why it is not the default; its overhead is
recorded in BENCH_obs.json but not asserted.

Overheads are the median of per-repeat back-to-back ratios (plain/future/
callback rotating within each repeat): each ratio cancels the clock drift
of its repeat and the median rejects outlier repeats — sequential best-of
measurement drifts by more than the claim itself on shared CI hosts.

Usage:  PYTHONPATH=src python -m benchmarks.obs_bench [--rounds 200]
            [--repeats 10] [--json BENCH_obs.json]
"""
import argparse
import json
import os
import tempfile
import time


def obs_overhead(rounds: int = 200, repeats: int = 10, json_path: str = None):
    import jax
    import numpy as np

    from benchmarks.rounds_bench import _problem
    from repro.core import rounds as rounds_lib
    from repro.obs import JsonlSink, MetricStream
    from repro.obs import sinks as obs_sinks

    # a realistically-sized round (~5 ms compute): the tap's host cost is
    # a fixed ~5-7 us/row, so the sub-ms toy problem rounds_bench uses
    # would measure the host's scheduler noise, not the tap
    step, state0, fl = _problem(n=8000, p=256, j=128, batch=200)
    inputs = rounds_lib.make_inputs(fl, 1, rounds, jax.random.PRNGKey(2))
    tmp = tempfile.mkdtemp(prefix="obs_bench_")
    jsonl_path = os.path.join(tmp, "rounds.jsonl")
    # stream.rows already keeps every row in memory for the exactness
    # check — a MemorySink on top would double the per-row sink cost
    stream = MetricStream([JsonlSink(jsonl_path)], log_every=1)
    stream_cb = MetricStream([], log_every=1, transport="callback")

    def run_plain():
        return rounds_lib.scan_rounds(step, state0, inputs)

    def run_obs():
        return stream.run(step, state0, inputs, driver="scan")

    def run_cb():
        return stream_cb.run(step, state0, inputs, driver="scan")

    # warmup/compile all three
    s_plain, m_plain = run_plain()
    jax.block_until_ready(s_plain.params)
    s_obs, m_obs = run_obs()
    jax.block_until_ready(s_obs.params)
    s_cb, _ = run_cb()
    jax.block_until_ready(s_cb.params)

    t_plain = t_obs = t_cb = float("inf")
    ratios, ratios_cb = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        s_plain, m_plain = run_plain()
        jax.block_until_ready(s_plain.params)
        dt_plain = time.perf_counter() - t0
        t_plain = min(t_plain, dt_plain)
        t0 = time.perf_counter()
        s_obs, m_obs = run_obs()
        jax.block_until_ready(s_obs.params)
        dt_obs = time.perf_counter() - t0
        t_obs = min(t_obs, dt_obs)
        t0 = time.perf_counter()
        s_cb, _ = run_cb()
        jax.block_until_ready(s_cb.params)
        dt_cb = time.perf_counter() - t0
        t_cb = min(t_cb, dt_cb)
        ratios.append(dt_obs / dt_plain)
        ratios_cb.append(dt_cb / dt_plain)
    # median of the per-repeat back-to-back ratios: each ratio cancels the
    # clock drift within its repeat, the median rejects outlier repeats
    overhead = float(np.median(ratios)) - 1.0
    overhead_cb = float(np.median(ratios_cb)) - 1.0
    # drain in-flight flushes before inspecting rows (streaming is async
    # by design; the timed region is training throughput, as in real runs)
    stream.sync()
    stream_cb.sync()

    for name, t in (("off", t_plain), ("on", t_obs), ("on_cb", t_cb)):
        print(f"obs_stream_{name},{1e6 * t / rounds:.1f},"
              f"rounds_per_s={rounds / t:.1f}", flush=True)
    print(f"obs_stream_overhead,0,overhead={100 * overhead:.2f}%"
          f",callback={100 * overhead_cb:.2f}%", flush=True)

    # exactness: trajectory and stacked metrics are bitwise-identical with
    # the stream on, and every streamed row equals the f32-cast stacked value
    for variant, s in (("future", s_obs), ("callback", s_cb)):
        for a, b in zip(jax.tree.leaves(s_plain), jax.tree.leaves(s)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), \
                f"active stream ({variant}) changed the trajectory"
    names = sorted(m_plain)
    for k in names:
        assert np.array_equal(np.asarray(m_plain[k]), np.asarray(m_obs[k])), \
            f"active stream changed stacked metric {k!r}"
    round_rows = [r for r in stream.rows if r["kind"] == "round"]
    # rows from ALL repeats + warmup; the last `rounds` are the final run
    round_rows = round_rows[-rounds:]
    assert len(round_rows) == rounds, \
        f"expected {rounds} streamed rows, got {len(round_rows)}"
    rows_exact = all(
        row[k] == float(np.float32(np.asarray(m_plain[k][row["t"] - 1])))
        for row in round_rows for k in names)
    assert rows_exact, "streamed rows != stacked metrics"
    with open(jsonl_path) as f:
        disk_rows = [json.loads(line) for line in f]
    assert [r for r in disk_rows if r["kind"] == "round"][-rounds:] \
        == round_rows, "JSONL sink rows drifted from in-memory rows"
    print(f"obs_stream_exact,0,rows={len(round_rows)},exact={rows_exact}",
          flush=True)

    result = {
        "rounds": rounds,
        "repeats": repeats,
        "rounds_per_s_off": rounds / t_plain,
        "rounds_per_s_on": rounds / t_obs,
        "overhead_frac": overhead,
        "overhead_frac_callback": overhead_cb,
        "rows_streamed": len(round_rows),
        "rows_exact": bool(rows_exact),
        "flush_every": stream.flush_every,
        "log_every": stream.log_every,
    }
    if json_path:
        obs_sinks.bench_json(json_path, result)

    assert overhead < 0.05, (
        f"active MetricStream overhead {100 * overhead:.2f}% >= 5% "
        f"({rounds / t_plain:.1f} -> {rounds / t_obs:.1f} rounds/s)")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--repeats", type=int, default=10)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    obs_overhead(rounds=args.rounds, repeats=args.repeats,
                 json_path=args.json)

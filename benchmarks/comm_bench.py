"""Bytes-on-wire vs final-loss tradeoff for the compression subsystem
(DESIGN.md §10) — the Fig.-3 axis, measured instead of asserted.

Runs the quickstart workload (Algorithm 1, the paper's two-layer swish net
on synthetic MNIST-shaped Gaussians) under every codec and records, per
codec: total/ per-round upload bytes from repro.comm.accounting, the
compression ratio over dense fp32, and the final training cost. Prints
``name,us_per_call,derived`` CSV rows like the other benches, writes the
curve to JSON (BENCH_comm.json in CI), and claim-checks the acceptance
criterion: int8 stochastic quantization within 2% relative final loss of
the uncompressed run at >= 3.5x fewer upload bytes.

Usage:  PYTHONPATH=src python -m benchmarks.comm_bench [--smoke]
            [--rounds 300] [--n 20000] [--json BENCH_comm.json]
"""
import argparse
import time


def comm_tradeoff(rounds: int = 300, n: int = 20_000, clients: int = 10,
                  json_path: str = None, topk_frac: float = 0.05):
    import jax
    import numpy as np

    from repro.comm import accounting, make_codec
    from repro.comm.codecs import tree_flat_dim
    from repro.configs.base import FLConfig
    from repro.core import algorithms, fed
    from repro.data.synthetic import classification_dataset
    from repro.models import mlp

    key = jax.random.PRNGKey(0)
    (z, y, _), _ = classification_dataset(key, n=n, num_features=784,
                                          num_classes=10, test_n=100,
                                          noise=4.0)
    params0 = mlp.init(jax.random.PRNGKey(1), 784, 64, 10)
    data = fed.partition_samples(z, y, num_clients=clients)
    fl = FLConfig(num_clients=clients, batch_size=100, a1=0.3, a2=0.3,
                  alpha_rho=0.1, alpha_gamma=0.6, tau=0.05, l2_lambda=1e-5)
    dim = tree_flat_dim(params0)

    def eval_fn(params, state):
        return {"cost": float(mlp.mean_loss(params, z, y))}

    results = []
    for name in ("none", "int8", "int4", "topk", "topk8"):
        codec = make_codec(name, topk_frac=topk_frac)
        t0 = time.perf_counter()
        r = algorithms.algorithm1(mlp.per_sample_loss, params0, data, fl,
                                  rounds, jax.random.PRNGKey(2),
                                  eval_fn=eval_fn, eval_every=rounds,
                                  codec=codec)
        jax.block_until_ready(r.params)
        wall = time.perf_counter() - t0
        up_total = float(np.asarray(r.history["round_upload_bytes"]).sum())
        res = {
            "codec": name, "rounds": rounds, "final_cost":
                float(r.history["cost"][-1]),
            "upload_bytes_total": up_total,
            "upload_bytes_per_round": up_total / rounds,
            "compression_ratio":
                accounting.compression_ratio(codec, dim) if codec else 1.0,
            "wall_s": wall,
        }
        results.append(res)
        print(f"comm_codec_{name},{1e6 * wall / rounds:.1f},"
              f"final_cost={res['final_cost']:.4f},"
              f"upload_bytes_per_round={res['upload_bytes_per_round']:.0f},"
              f"ratio={res['compression_ratio']:.2f}x", flush=True)

    if json_path:
        from repro.obs import sinks as obs_sinks
        obs_sinks.bench_json(json_path, results)

    # acceptance claim-check (ISSUE 2): int8 within 2% at >= 3.5x fewer bytes
    dense = next(r for r in results if r["codec"] == "none")
    int8 = next(r for r in results if r["codec"] == "int8")
    rel = abs(int8["final_cost"] - dense["final_cost"]) / dense["final_cost"]
    ratio = dense["upload_bytes_total"] / int8["upload_bytes_total"]
    print(f"comm_int8_claim,0,rel_loss_gap={rel:.4f},bytes_ratio={ratio:.2f}x",
          flush=True)
    assert rel < 0.02, f"int8 final-loss gap {rel:.3%} exceeds 2%"
    assert ratio >= 3.5, f"int8 byte ratio {ratio:.2f}x below 3.5x"
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (~1 min CPU)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--topk-frac", type=float, default=0.05)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rounds = args.rounds or (60 if args.smoke else 300)
    n = args.n or (2_000 if args.smoke else 20_000)
    comm_tradeoff(rounds=rounds, n=n, json_path=args.json,
                  topk_frac=args.topk_frac)


if __name__ == "__main__":
    main()

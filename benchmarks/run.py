"""Benchmark harness — one function per paper table/figure plus the roofline
table and kernel micro-benches. Prints ``name,us_per_call,derived`` CSV.

Usage:  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    args = ap.parse_args()

    from benchmarks import (dp_bench, extensions_bench, figures,
                            kernels_bench, obs_bench, rounds_bench,
                            scale_bench)
    benches = [
        ("rounds_scan_vs_loop", rounds_bench.rounds_scan_vs_loop),
        ("scale_cohort_engine", scale_bench.scale_smoke),
        ("obs_stream_overhead", obs_bench.obs_overhead),
        ("fig1_unconstrained_sample_based", figures.fig1_unconstrained_sample_based),
        ("fig1ef_constrained_sample_based", figures.fig1ef_constrained_sample_based),
        ("fig2_feature_based", figures.fig2_feature_based),
        ("fig3_comm_comp_tradeoff", figures.fig3_comm_comp_tradeoff),
        ("fig4_sparsity_cost_tradeoff", figures.fig4_sparsity_cost_tradeoff),
        ("ext1_local_updates", extensions_bench.ext1_local_updates),
        ("ext2_dp_uploads", extensions_bench.ext2_dp_uploads),
        ("dp_privacy_frontier", dp_bench.dp_privacy_frontier),
        ("kernel_microbench", kernels_bench.kernel_microbench),
        ("roofline_table", kernels_bench.roofline_table),
    ]
    failed = []
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            fn()
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except AssertionError as e:
            failed.append(name)
            print(f"# {name} CLAIM-CHECK FAILED: {e}", flush=True)
        except Exception as e:
            failed.append(name)
            print(f"# {name} ERROR: {type(e).__name__}: {e}", flush=True)
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)
    print("# all benchmarks passed")


if __name__ == '__main__':
    main()

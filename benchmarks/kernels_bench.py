"""Micro-benchmarks of the kernel reference paths (CPU) + roofline table from
the dry-run artifacts. On TPU the Pallas paths replace the ref ops; wall-times
here are CPU sanity numbers, the roofline table is the TPU-target projection."""
from __future__ import annotations

import glob
import json
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / reps * 1e6


def kernel_microbench():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (512, 2048))
    sc = jnp.zeros((2048,))
    f = jax.jit(lambda a, b: ref.rmsnorm_ref(a, b))
    print(f"kern.rmsnorm.512x2048,{_time(f, x, sc):.0f},ref_cpu", flush=True)

    w = jax.random.normal(jax.random.fold_in(key, 4), (1 << 20,))
    buf = jnp.zeros((1 << 20,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (1 << 20,))
    f = jax.jit(lambda a, b, c: ref.ssca_update_ref(a, b, c, 0.5, 0.3, 0.2, 1e-5))
    print(f"kern.ssca_update.1M,{_time(f, w, buf, g):.0f},ref_cpu", flush=True)

    q = jax.random.normal(jax.random.fold_in(key, 5), (1, 8, 512, 64))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 512, 64))
    v = jax.random.normal(jax.random.fold_in(key, 3), (1, 2, 512, 64))
    f = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c))
    print(f"kern.flash_attn.512,{_time(f, q, k, v):.0f},ref_cpu", flush=True)


def roofline_table(result_dir="results/dryrun"):
    """§Roofline: the per-(arch x shape x mesh) three-term table."""
    files = sorted(glob.glob(f"{result_dir}/*.json"))
    if not files:
        print("roofline.table,0,no dry-run artifacts found (run scripts/dryrun_sweep.sh)")
        return
    print("# arch,shape,mesh,status,bottleneck,compute_ms,memory_ms,"
          "collective_ms,useful_ratio,hbm_gb_per_dev")
    for f in files:
        r = json.load(open(f))
        r = r[0] if isinstance(r, list) else r
        if r.get("status") != "ok":
            print(f"roofline.{r.get('arch')}.{r.get('shape')}.{r.get('mesh','?')},"
                  f"0,{r.get('status')}:{str(r.get('why', r.get('error','')))[:40]}")
            continue
        mem = r.get("memory") or {}
        peak = (mem.get("peak_bytes") or 0) / 1e9
        print(f"roofline.{r['arch']}.{r['shape']}.{r['mesh']},0,"
              f"{r['bottleneck']};c={r['compute_s']*1e3:.1f}ms;"
              f"m={r['memory_s']*1e3:.1f}ms;x={r['collective_s']*1e3:.1f}ms;"
              f"useful={r.get('useful_flop_ratio', 0):.2f};peak={peak:.2f}GB",
              flush=True)

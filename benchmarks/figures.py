"""One benchmark per paper table/figure (§VI), on the synthetic MNIST-shaped
task (offline container; see DESIGN.md §7). Scales are reduced for CPU wall
time; every comparison preserves the paper's per-round compute matching
(B for SSCA vs B_loc·E for sample-based SGD, B for feature-based)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import algorithms, baselines, fed
from repro.core.baselines import SGDConfig
from repro.data.synthetic import classification_dataset
from repro.models import mlp

P, J, L, N, I = 784, 64, 10, 20_000, 10
ROUNDS = 300
EVERY = 50


def _problem(seed=0):
    key = jax.random.PRNGKey(seed)
    (z, y, lab), (zt, yt, labt) = classification_dataset(
        key, n=N, num_features=P, num_classes=L, test_n=2000, noise=4.0)
    params0 = mlp.init(jax.random.PRNGKey(1), P, J, L)
    return z, y, zt, labt, params0


def psl(p, z, y):
    return mlp.per_sample_loss(p, z, y)


def _eval(z, y, zt, labt):
    def eval_fn(params, state):
        out = {"cost": float(mlp.mean_loss(params, z[:4000], y[:4000])),
               "acc": float(mlp.accuracy(params, zt, labt))}
        if hasattr(state, "slack"):
            out["slack"] = float(state.slack)
        return out
    return eval_fn


def _row(name, t0, rounds, hist, extra=""):
    us = (time.time() - t0) * 1e6 / max(rounds, 1)
    cost = float(np.asarray(hist["cost"])[-1]) if "cost" in hist else float("nan")
    acc = float(np.asarray(hist["acc"])[-1]) if "acc" in hist else float("nan")
    print(f"{name},{us:.0f},cost={cost:.4f};acc={acc:.4f}{extra}", flush=True)
    return cost, acc


def fig1_unconstrained_sample_based():
    """Fig. 1(a)-(d): Alg 1 vs sample-based SGD [5],[6] and SGD-m [7] at equal
    per-round computation (B vs B_loc x E)."""
    z, y, zt, labt, params0 = _problem()
    data = fed.partition_samples(z, y, I)
    ev = _eval(z, y, zt, labt)
    results = {}
    for B in (10, 100):
        fl = FLConfig(batch_size=B, a1=0.9 if B == 10 else 0.3,
                      a2=0.5 if B == 10 else 0.3, alpha_rho=0.1,
                      alpha_gamma=0.6, tau=0.2 if B == 10 else 0.05,
                      l2_lambda=1e-5)
        t0 = time.time()
        r = algorithms.algorithm1(psl, params0, data, fl, ROUNDS,
                                  jax.random.PRNGKey(2), ev, EVERY)
        results[f"alg1_B{B}"] = _row(f"fig1.alg1.B{B}", t0, ROUNDS, r.history)
        t0 = time.time()
        r = baselines.sample_sgd(psl, params0, data,
                                 SGDConfig(lr_a=0.3, lr_alpha=0.3,
                                           local_steps=1, local_batch=B),
                                 ROUNDS, jax.random.PRNGKey(2), ev, EVERY)
        results[f"sgd_B{B}"] = _row(f"fig1.fedsgd.B{B}E1", t0, ROUNDS, r.history)
        t0 = time.time()
        r = baselines.sample_sgd(psl, params0, data,
                                 SGDConfig(lr_a=0.3, lr_alpha=0.0, momentum=0.1,
                                           local_steps=5, local_batch=max(B // 5, 2)),
                                 ROUNDS, jax.random.PRNGKey(2), ev, EVERY,
                                 momentum=True)
        results[f"sgdm_B{B}"] = _row(f"fig1.sgdm.B{B // 5}E5", t0, ROUNDS, r.history)
    # paper claim: SSCA converges faster than FedSGD at equal per-round compute
    for B in (10, 100):
        assert results[f"alg1_B{B}"][0] < results[f"sgd_B{B}"][0] * 1.05, \
            f"fig1 ordering violated at B={B}"
    return results


def fig1ef_constrained_sample_based():
    """Fig. 1(e)-(f): Alg 2 — training cost pinned at U, slack -> 0."""
    z, y, zt, labt, params0 = _problem()
    data = fed.partition_samples(z, y, I)
    ev = _eval(z, y, zt, labt)
    out = {}
    for B in (10, 100):
        fl = FLConfig(batch_size=B, a1=0.9, a2=0.5, alpha_rho=0.1,
                      alpha_gamma=0.6, tau=0.2, constrained=True,
                      cost_limit=0.5, penalty_c=1e4)
        t0 = time.time()
        r = algorithms.algorithm2(psl, params0, data, fl, 400,
                                  jax.random.PRNGKey(3), ev, 100)
        cost, acc = _row(f"fig1ef.alg2.B{B}", t0, 400, r.history,
                         extra=f";slack={float(np.asarray(r.history['slack'])[-1]):.2e}")
        out[B] = (cost, acc)
    return out


def fig2_feature_based():
    """Fig. 2: Alg 3 vs feature-based SGD/SGD-m [13] (same info collection)."""
    z, y, zt, labt, params0 = _problem()
    fdata = fed.partition_features(z, y, I)
    pi = fdata.feature_blocks.shape[-1]
    w1p = jnp.pad(params0["w1"], ((0, 0), (0, I * pi - P)))
    fparams0 = {"w0": params0["w0"],
                "blocks": w1p.reshape(J, I, pi).transpose(1, 0, 2)}

    def ev(p, s):
        hsum = sum(mlp.client_h(p["blocks"][i], fdata.feature_blocks[i][:4000])
                   for i in range(I))
        cost = float(jnp.mean(mlp.per_sample_loss_from_h(p["w0"], hsum, y[:4000])))
        return {"cost": cost, "acc": float("nan")}

    results = {}
    for B in (10, 100):
        fl = FLConfig(batch_size=B, a1=0.9, a2=0.3 if B == 10 else 0.5,
                      alpha_rho=0.3 if B == 10 else 0.1, alpha_gamma=0.6,
                      tau=0.1 if B == 10 else 0.2, l2_lambda=1e-5,
                      mode="feature")
        t0 = time.time()
        r = algorithms.algorithm3(mlp.per_sample_loss_from_h, mlp.client_h,
                                  fparams0, fdata, fl, ROUNDS,
                                  jax.random.PRNGKey(4), ev, EVERY)
        results[f"alg3_B{B}"] = _row(f"fig2.alg3.B{B}", t0, ROUNDS, r.history)
        for mom, name in ((False, "sgd"), (True, "sgdm")):
            t0 = time.time()
            r = baselines.feature_sgd(
                mlp.per_sample_loss_from_h, mlp.client_h, fparams0, fdata,
                SGDConfig(lr_a=0.3, lr_alpha=0.0 if mom else 0.3,
                          momentum=0.1 if mom else 0.0, local_batch=B),
                ROUNDS, jax.random.PRNGKey(4), ev, EVERY, momentum=mom)
            results[f"{name}_B{B}"] = _row(f"fig2.{name}.B{B}", t0, ROUNDS,
                                           r.history)
    for B in (10, 100):
        assert results[f"alg3_B{B}"][0] < results[f"sgd_B{B}"][0] * 1.05, \
            f"fig2 ordering violated at B={B}"
    return results


def fig3_comm_comp_tradeoff(target=0.45):
    """Fig. 3: rounds (communication cost) to reach a target training cost vs
    per-round computation cost (B or B_loc·E)."""
    z, y, zt, labt, params0 = _problem()
    data = fed.partition_samples(z, y, I)

    def rounds_to_target(run_fn, rounds=500):
        r = run_fn(rounds)
        cost = np.asarray(r.history["cost"])
        rr = np.asarray(r.history["round"])
        hit = np.nonzero(cost <= target)[0]
        return int(rr[hit[0]]) if len(hit) else -1

    ev = _eval(z, y, zt, labt)
    print("# fig3: rounds-to-target(cost<=%.2f) vs per-round compute" % target)
    for B in (10, 50, 100, 200):
        fl = FLConfig(batch_size=B, a1=0.3, a2=0.3, alpha_rho=0.1,
                      alpha_gamma=0.6, tau=0.05, l2_lambda=1e-5)
        n1 = rounds_to_target(lambda rr: algorithms.algorithm1(
            psl, params0, data, fl, rr, jax.random.PRNGKey(5), ev, 25))
        n2 = rounds_to_target(lambda rr: baselines.sample_sgd(
            psl, params0, data, SGDConfig(lr_a=0.3, lr_alpha=0.3,
                                          local_steps=1, local_batch=B),
            rr, jax.random.PRNGKey(5), ev, 25))
        print(f"fig3.B{B},0,alg1_rounds={n1};fedsgd_rounds={n2}", flush=True)


def fig4_sparsity_cost_tradeoff():
    """Fig. 4: model-norm vs training-cost tradeoff — Alg 1 sweeping λ vs
    Alg 2 sweeping U (Theorem 5: the two formulations trace the same curve)."""
    z, y, zt, labt, params0 = _problem()
    data = fed.partition_samples(z, y, I)
    rows = []
    for lam in (1e-5, 1e-4, 1e-3):
        fl = FLConfig(batch_size=100, a1=0.3, a2=0.3, alpha_rho=0.1,
                      alpha_gamma=0.6, tau=0.05, l2_lambda=lam)
        r = algorithms.algorithm1(psl, params0, data, fl, ROUNDS,
                                  jax.random.PRNGKey(6),
                                  lambda p, s: {"cost": float(mlp.mean_loss(
                                      p, z[:4000], y[:4000])),
                                      "l2": float(mlp.l2_sq(p))}, ROUNDS // 2)
        cost = float(np.asarray(r.history["cost"])[-1])
        l2 = float(np.asarray(r.history["l2"])[-1])
        rows.append(("alg1", lam, cost, l2))
        print(f"fig4.alg1.lam{lam:g},0,cost={cost:.4f};l2={l2:.2f}", flush=True)
    for u in (0.4, 0.7, 1.0):
        fl = FLConfig(batch_size=100, a1=0.9, a2=0.5, alpha_rho=0.1,
                      alpha_gamma=0.6, tau=0.2, constrained=True,
                      cost_limit=u, penalty_c=1e4)
        r = algorithms.algorithm2(psl, params0, data, fl, 400,
                                  jax.random.PRNGKey(6),
                                  lambda p, s: {"cost": float(mlp.mean_loss(
                                      p, z[:4000], y[:4000])),
                                      "l2": float(mlp.l2_sq(p))}, 200)
        cost = float(np.asarray(r.history["cost"])[-1])
        l2 = float(np.asarray(r.history["l2"])[-1])
        rows.append(("alg2", u, cost, l2))
        print(f"fig4.alg2.U{u:g},0,cost={cost:.4f};l2={l2:.2f}", flush=True)
    # Theorem 5 behaviour: lower U (tighter cost) => larger l2, and vice versa
    alg2 = [r for r in rows if r[0] == "alg2"]
    l2s = [r[3] for r in sorted(alg2, key=lambda r: r[1])]
    assert l2s == sorted(l2s, reverse=True), f"fig4 monotonicity violated: {l2s}"
    return rows

"""Device-sharded vs local client-execution throughput (DESIGN.md §11).

Runs the same Algorithm-1 round chain (I clients, quickstart-shaped MLP,
scan-compiled K-round dispatch) under ``topology=local`` (vmap over all
clients on one device — the reference engine) and ``topology=sharded``
(clients over an 8-virtual-device mesh via shard_map, eq.-(9) aggregation as
a weighted psum), and reports rounds/second for each. Prints
``name,us_per_call,derived`` CSV rows like the other benches and writes the
result to JSON (``BENCH_shard.json`` in CI).

Claim checks:
  * trajectory equality (always enforced): the sharded per-round loss
    trajectory matches local at atol 1e-5 — the collective path computes the
    same mathematics, only reassociated.
  * speedup >= 1.5x (enforced when the host has >= 2 cores per device):
    distributing I/D clients per device beats single-device vmap once real
    parallel hardware exists. The single-device baseline is not serial —
    XLA's intra-op threading spreads it over every core — so beating it
    1.5x needs cores beyond what one device program saturates; on hosts
    without that headroom (the 2-vCPU CI runners, or cpu_count == devices)
    the measured speedup is still recorded in the JSON and the claim is
    marked "gated" instead of asserted (same best-effort stance as
    rounds_bench's timing claim on shared runners).

The virtual-device count is forced in-process (XLA_FLAGS must be set before
jax initializes), so this bench is runnable anywhere:

Usage:  PYTHONPATH=src python -m benchmarks.shard_bench [--smoke]
            [--clients 64] [--devices 8] [--rounds 120]
            [--json BENCH_shard.json]
"""
import argparse
import os
import sys
import time


def _force_devices(n: int):
    if "jax" in sys.modules:
        raise RuntimeError("benchmarks.shard_bench must set "
                           "--xla_force_host_platform_device_count before "
                           "jax is imported; run it as the entry point")
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --xla_force_host_platform_device_count={n}")


def shard_tradeoff(rounds: int = 120, clients: int = 64, devices: int = 8,
                   per_client: int = 200, batch: int = 100, repeats: int = 3,
                   json_path: str = None):
    import jax
    import numpy as np

    from repro.comm.accounting import psum_axis_bytes
    from repro.comm.codecs import tree_flat_dim
    from repro.configs.base import FLConfig
    from repro.core import algorithms, fed, optimizer
    from repro.core import rounds as rounds_lib
    from repro.core.topology import ShardedTopology
    from repro.data.synthetic import classification_dataset
    from repro.launch.mesh import make_client_mesh
    from repro.models import mlp

    assert len(jax.devices()) >= devices, (
        f"{devices} devices requested, {len(jax.devices())} present")
    key = jax.random.PRNGKey(0)
    (z, y, _), _ = classification_dataset(key, n=clients * per_client,
                                          num_features=784, num_classes=10,
                                          test_n=100, noise=4.0)
    data = fed.partition_samples(z, y, num_clients=clients)
    params0 = mlp.init(jax.random.PRNGKey(1), 784, 64, 10)
    fl = FLConfig(num_clients=clients, batch_size=batch, a1=0.3, a2=0.3,
                  alpha_rho=0.1, alpha_gamma=0.6, tau=0.05, l2_lambda=1e-5)
    topo = ShardedTopology(make_client_mesh(devices))
    dim = tree_flat_dim(params0)

    inputs = rounds_lib.make_inputs(fl, 1, rounds, jax.random.PRNGKey(2))
    state0 = optimizer.ssca_init(params0)

    def run(topology):
        step = algorithms.make_algorithm1_step(mlp.per_sample_loss, data, fl,
                                               topology=topology)
        s, m = rounds_lib.scan_rounds(step, state0, inputs)   # compile+warm
        jax.block_until_ready(s.params)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            s, m = rounds_lib.scan_rounds(step, state0, inputs)
            jax.block_until_ready(s.params)
            best = min(best, time.perf_counter() - t0)
        return s, m, best

    s_local, m_local, t_local = run(None)
    s_shard, m_shard, t_shard = run(topo)

    traj_diff = float(np.max(np.abs(np.asarray(m_shard["loss_est"])
                                    - np.asarray(m_local["loss_est"]))))
    speedup = t_local / t_shard
    cpus = os.cpu_count() or 1
    # >= 2 cores per device shard: the local baseline's intra-op threads
    # already use every core, so device parallelism only has real headroom
    # when cores clearly exceed what one device program saturates
    claim_active = cpus >= 2 * devices
    result = {
        "clients": clients, "devices": devices, "cpu_count": cpus,
        "rounds": rounds, "batch": batch, "param_dim": dim,
        "local_rounds_per_s": rounds / t_local,
        "sharded_rounds_per_s": rounds / t_shard,
        "speedup": speedup,
        "traj_max_abs_diff": traj_diff,
        "axis_bytes_per_round": psum_axis_bytes(dim, devices),
        "upload_bytes_per_round": float(m_local["upload_bytes"][0]),
        "claim": ("pass" if claim_active and speedup >= 1.5 else
                  "fail" if claim_active else "gated"),
        "claim_note": (None if claim_active else
                       f"{cpus} cores < 2x{devices} devices: single-device "
                       "intra-op threading already saturates the host, no "
                       "parallel headroom to claim-check against"),
    }

    for name, t in (("local", t_local), ("sharded", t_shard)):
        print(f"shard_topology_{name},{1e6 * t / rounds:.1f},"
              f"rounds_per_s={rounds / t:.1f}", flush=True)
    print(f"shard_topology_speedup,0,sharded_over_local={speedup:.2f}x,"
          f"claim={result['claim']},traj_max_abs_diff={traj_diff:.2e}",
          flush=True)

    if json_path:
        from repro.obs import sinks as obs_sinks
        obs_sinks.bench_json(json_path, result)

    # trajectory equality is the hard invariant on every host
    np.testing.assert_allclose(np.asarray(m_shard["loss_est"]),
                               np.asarray(m_local["loss_est"]), atol=1e-5)
    for a, b in zip(jax.tree.leaves(s_shard.params),
                    jax.tree.leaves(s_local.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    if claim_active:
        assert speedup >= 1.5, (
            f"sharded topology {rounds / t_shard:.1f} rps is only "
            f"{speedup:.2f}x local {rounds / t_local:.1f} rps "
            f"(>= 1.5x required on a {cpus}-core host with {devices} "
            "devices)")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (~1 min CPU)")
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    _force_devices(args.devices)
    rounds = args.rounds or (40 if args.smoke else 120)
    shard_tradeoff(rounds=rounds, clients=args.clients, devices=args.devices,
                   json_path=args.json)


if __name__ == "__main__":
    main()

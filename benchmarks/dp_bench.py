"""DP frontier benchmark (DESIGN.md §15): ε vs final loss for the dp=
clip+noise upload stage composed with the int8+EF codec path, at equal
rounds, via the same Algorithm-1 driver as the non-private runs.

Claims checked:

* bytes-on-wire are UNCHANGED by DP — the clip+noise stage runs before
  codec encode, so every round's ``upload_bytes`` under dp= equals the
  non-DP int8 run exactly (asserted per-round, not just the total);
* the streamed ε matches the subsampled-RDP accountant's end-of-run
  ``epsilon_total`` recorded in the manifest block;
* (full mode only) the frontier is monotone: smaller ε (more noise) never
  *improves* final training cost.

Emits BENCH_dp.json: one row per ε ∈ {∞, 8, 2, 0.5} with final cost, test
accuracy, realized ε, noise multiplier, and per-round upload bytes.

Usage:  PYTHONPATH=src python -m benchmarks.dp_bench [--smoke]
            [--rounds 200] [--json BENCH_dp.json]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.comm import make_codec
from repro.configs.base import FLConfig
from repro.core import algorithms, fed, privacy
from repro.data.synthetic import classification_dataset
from repro.models import mlp

EPS_SWEEP = (None, 8.0, 2.0, 0.5)       # None = non-private baseline
CLIP = 5.0
DELTA = 1e-5


def _problem():
    key = jax.random.PRNGKey(0)
    (z, y, _), (zt, _, labt) = classification_dataset(
        key, n=10_000, num_features=128, num_classes=10, test_n=1000,
        noise=4.0)
    params0 = mlp.init(jax.random.PRNGKey(1), 128, 32, 10)
    data = fed.partition_samples(z, y, 10)
    return z, y, zt, labt, params0, data


def dp_privacy_frontier(rounds: int = 200, json_path: str | None = None):
    z, y, zt, labt, params0, data = _problem()
    fl = FLConfig(batch_size=32, a1=0.9, a2=0.5, alpha_rho=0.1,
                  alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5)
    psl = mlp.per_sample_loss

    results = []
    for eps in EPS_SWEEP:
        dp = (None if eps is None else
              privacy.DPConfig(clip_norm=CLIP, epsilon=eps, delta=DELTA))
        r = algorithms.algorithm1(psl, params0, data, fl, rounds,
                                  jax.random.PRNGKey(3),
                                  codec=make_codec("int8"), dp=dp)
        cost = float(mlp.mean_loss(r.params, z[:4000], y[:4000]))
        acc = float(mlp.accuracy(r.params, zt, labt))
        row = {"epsilon": eps, "cost": cost, "acc": acc,
               "upload_bytes": np.asarray(
                   r.history["round_upload_bytes"], np.float64),
               "noise_multiplier": (None if dp is None
                                    else privacy.noise_multiplier(dp))}
        if dp is not None:
            eps_stream = float(
                np.asarray(r.history["round_dp_epsilon"])[-1])
            eps_manifest = privacy.manifest_info(
                dp, 1.0, rounds=rounds)["epsilon_total"]
            # streamed in-graph ε (float32 constants) vs the host-side
            # accountant — must be the same number
            assert abs(eps_stream - eps_manifest) <= 1e-4 * eps_manifest, (
                eps_stream, eps_manifest)
            row["epsilon_realized"] = eps_stream
        results.append(row)
        tag = "inf" if eps is None else eps
        print(f"dp.frontier.eps{tag},0,cost={cost:.4f};acc={acc:.4f};"
              f"bytes={row['upload_bytes'].sum():.0f}", flush=True)

    # bytes-on-wire invariance: DP runs before the codec, so every DP run's
    # per-round wire bytes equal the non-DP int8 run's exactly
    base_bytes = results[0]["upload_bytes"]
    for row in results[1:]:
        np.testing.assert_array_equal(row["upload_bytes"], base_bytes), \
            row["epsilon"]
    print(f"dp.frontier.bytes_invariant,0,per_round={base_bytes[0]:.0f}",
        flush=True)

    # frontier monotonicity only at full horizon — a smoke run's handful of
    # rounds is inside the noise floor
    if rounds >= 100:
        costs = {row["epsilon"]: row["cost"] for row in results}
        assert costs[0.5] >= costs[8.0] - 0.05, costs
        assert costs[8.0] >= costs[None] - 0.05, costs

    if json_path:
        from repro.obs import sinks as obs_sinks
        payload = [{k: (v.sum() if k == "upload_bytes" else v)
                    for k, v in row.items()} for row in results]
        obs_sinks.bench_json(
            json_path,
            {"rounds": rounds, "clip_norm": CLIP, "delta": DELTA,
             "frontier": payload},
            config=fl, codec=make_codec("int8"),
            extra={"dp_sweep": [e for e in EPS_SWEEP if e is not None]})
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--smoke", action="store_true",
                    help="few rounds, skip the frontier-shape assertion")
    ap.add_argument("--json", default=None, help="write BENCH_dp.json here")
    args = ap.parse_args()
    dp_privacy_frontier(rounds=30 if args.smoke else args.rounds,
                        json_path=args.json)


if __name__ == "__main__":
    main()

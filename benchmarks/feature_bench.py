"""Constrained vertical-FL benchmark: Algorithm 4 vs baselines on KKT
residuals (DESIGN.md §12).

Scenario — the paper's formulation (40) under the feature-based composition:
min ‖ω‖² s.t. F(ω) <= U, with F the full-data loss of the vertically-split
MLP (I feature clients, h-exchange information collection). Three methods
run the SAME per-round protocol (fed.feature_round: h-exchange + head/block
q-uploads — equal rounds, equal upload bytes) and differ only in the update:

  * algorithm4      — the paper's mini-batch SSCA with the Lemma-1 dual step
  * frank_wolfe     — projection-free federated Frank-Wolfe (Dadras et al.):
                      exact-penalty objective over an L2 ball, LMO steps
  * dual_decomp     — dual decomposition / Arrow-Hurwicz (Fan et al.):
                      primal descent on the Lagrangian + projected dual ascent

Each method's trajectory is scored on full-batch KKT residuals
(core/solvers.kkt_residuals): stationarity ‖∇f0 + ν∇F‖, constraint
violation max(F−U, 0), complementary slackness. The residual is a property
of the ITERATE, not of an algorithm's internal dual state, so every method
is scored at the stationarity-minimizing valid multiplier
(solvers.kkt_best_nu) — the most favorable ν for each, which in particular
means dual-free Frank-Wolfe is not handicapped and algorithm4 gets no
credit for carrying its own ν (its Lemma-1 ν is recorded separately).

Claim checks:
  * trajectory equality (always enforced): algorithm4 under the sharded
    feature topology (clients on a "model"-axis mesh, h-exchange as a tiled
    all_gather) matches the local vmap reference at atol 1e-5.
  * finite KKT residuals for every method at every checkpoint (always
    enforced).
  * algorithm4 reaches a LOWER final KKT residual (stationarity +
    violation) than both baselines at equal rounds (the paper's Theorem-4
    KKT convergence, measured not asserted).

Prints ``name,us_per_call,derived`` CSV rows like the other benches and
writes the result to JSON (``BENCH_feature.json`` in CI).

Usage:  PYTHONPATH=src python -m benchmarks.feature_bench [--smoke]
            [--clients 4] [--devices 4] [--rounds 500]
            [--json BENCH_feature.json]
"""
import argparse
import os
import sys
import time


def _force_devices(n: int):
    if "jax" in sys.modules:
        raise RuntimeError("benchmarks.feature_bench must set "
                           "--xla_force_host_platform_device_count before "
                           "jax is imported; run it as the entry point")
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --xla_force_host_platform_device_count={n}")


def feature_constrained_bench(rounds: int = 600, clients: int = 4,
                              n: int = 4000, batch: int = 256,
                              cost_limit: float = 1.0, repeats: int = 3,
                              json_path: str = None):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.comm.accounting import all_gather_axis_bytes
    from repro.configs.base import FLConfig
    from repro.core import algorithms, baselines, fed, solvers
    from repro.core import rounds as rounds_lib
    from repro.core import topology as topology_lib
    from repro.data.synthetic import classification_dataset
    from repro.models import mlp

    classes, hidden, features = 4, 16, 32
    key = jax.random.PRNGKey(0)
    (z, y, _), _ = classification_dataset(key, n=n, num_features=features,
                                          num_classes=classes, test_n=10,
                                          noise=1.0)
    data = fed.partition_features(z, y, clients)
    pi = data.feature_blocks.shape[-1]
    params0 = {"w0": jax.random.normal(key, (classes, hidden)) * 0.2,
               "blocks": jax.random.normal(jax.random.fold_in(key, 1),
                                           (clients, hidden, pi)) * 0.2}
    # aggressive-early/fast-decay schedule: gamma(1) clips to 1, gamma ~ 2/t^0.6
    # late — satisfies (6) strictly and reaches a tight KKT point in few rounds
    fl = FLConfig(batch_size=batch, a1=0.9, a2=2.0, alpha_rho=0.2,
                  alpha_gamma=0.6, tau=0.1, constrained=True,
                  cost_limit=cost_limit, penalty_c=1e4, mode="feature")
    topo = topology_lib.feature_sharded_for(clients)
    run_key = jax.random.PRNGKey(2)
    every = max(rounds // 10, 1)

    # full-batch F(ω) and ∇F(ω) for the KKT yardstick (all I blocks composed)
    @jax.jit
    def F_and_grad(p):
        def F(p_):
            hsum = jnp.einsum("inp,ijp->nj", data.feature_blocks,
                              p_["blocks"])
            return jnp.mean(mlp.per_sample_loss_from_h(p_["w0"], hsum, y))
        return jax.value_and_grad(F)(p)

    def kkt_eval(own_nu_fn=None):
        def ev(p, s):
            fv, fg = F_and_grad(p)
            obj_g = jax.tree.map(lambda x: 2.0 * x, p)
            nu = solvers.kkt_best_nu(obj_g, fg)
            r = solvers.kkt_residuals(obj_g, [fg],
                                      jnp.asarray([fv - cost_limit]), nu)
            out = {"stationarity": float(r["stationarity"]),
                   "violation": float(r["violation"]),
                   "comp_slack": float(r["comp_slack"]),
                   "F": float(fv)}
            if own_nu_fn is not None:      # the method's carried multiplier
                out["nu_own"] = float(own_nu_fn(s))
            return out
        return ev

    def run_alg4(topology, eval_fn=None, ev=0):
        return algorithms.algorithm4(
            mlp.per_sample_loss_from_h, mlp.client_h, params0, data, fl,
            rounds, run_key, eval_fn=eval_fn, eval_every=ev,
            topology=topology)

    wall = {}

    def timed(name, thunk):
        thunk()                                   # compile + warm
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            r = thunk()
            jax.block_until_ready(r.params)
            best = min(best, time.perf_counter() - t0)
        wall[name] = best
        return r

    # trajectory equality: sharded == local, plus rounds/sec for both
    r4_local = timed("alg4_local", lambda: run_alg4(None))
    r4_shard = timed("alg4_sharded", lambda: run_alg4(topo))
    traj_diff = float(np.max(np.abs(
        np.asarray(r4_shard.history["round_loss_est"])
        - np.asarray(r4_local.history["round_loss_est"]))))

    # KKT-scored runs (eval chunks break the scan at `every` rounds)
    own_nu = lambda s: rounds_lib.unwrap_comm(s).nu
    r4 = run_alg4(None, kkt_eval(own_nu), every)
    rfw = baselines.feature_frank_wolfe(
        mlp.per_sample_loss_from_h, mlp.client_h, params0, data, fl,
        baselines.FWConfig(radius=10.0, penalty=10.0), rounds, run_key,
        eval_fn=kkt_eval(), eval_every=every)
    rdd = baselines.feature_dual_decomposition(
        mlp.per_sample_loss_from_h, mlp.client_h, params0, data, fl,
        baselines.DualConfig(), rounds, run_key,
        eval_fn=kkt_eval(own_nu), eval_every=every)

    methods = {"algorithm4": r4, "frank_wolfe": rfw, "dual_decomp": rdd}

    def series(r, k):
        return [float(v) for v in np.asarray(r.history[k])]

    def kkt_total(r):
        return (np.asarray(r.history["stationarity"])
                + np.asarray(r.history["violation"]))

    finite = all(np.isfinite(kkt_total(r)).all() and
                 np.isfinite(np.asarray(r.history["comp_slack"])).all()
                 for r in methods.values())
    finals = {name: float(kkt_total(r)[-1]) for name, r in methods.items()}
    alg4_wins = (finals["algorithm4"] < finals["frank_wolfe"]
                 and finals["algorithm4"] < finals["dual_decomp"])

    h_elems = clients * batch * hidden
    result = {
        "clients": clients, "devices": topo.num_shards, "rounds": rounds,
        "batch": batch, "n": n, "cost_limit": cost_limit,
        "traj_max_abs_diff": traj_diff,
        "local_rounds_per_s": rounds / wall["alg4_local"],
        "sharded_rounds_per_s": rounds / wall["alg4_sharded"],
        "axis_bytes_per_round": all_gather_axis_bytes(h_elems,
                                                      topo.num_shards),
        "upload_bytes_per_round": float(
            r4_local.history["round_upload_bytes"][0]),
        "kkt": {name: dict(
                    {"round": series(r, "round"),
                     "stationarity": series(r, "stationarity"),
                     "violation": series(r, "violation"),
                     "comp_slack": series(r, "comp_slack"),
                     "F": series(r, "F"),
                     "final_total": finals[name]},
                    **({"nu_own": series(r, "nu_own")}
                       if "nu_own" in r.history else {}))
                for name, r in methods.items()},
        "claim": "pass" if (alg4_wins and finite and traj_diff <= 1e-5)
                 else "fail",
    }

    for name, t in (("local", wall["alg4_local"]),
                    ("sharded", wall["alg4_sharded"])):
        print(f"feature_alg4_{name},{1e6 * t / rounds:.1f},"
              f"rounds_per_s={rounds / t:.1f}", flush=True)
    for name in methods:
        print(f"feature_kkt_{name},0,final_total={finals[name]:.4g},"
              f"stationarity={series(methods[name], 'stationarity')[-1]:.4g},"
              f"violation={series(methods[name], 'violation')[-1]:.4g}",
              flush=True)
    print(f"feature_claim,0,claim={result['claim']},"
          f"traj_max_abs_diff={traj_diff:.2e}", flush=True)

    if json_path:
        from repro.obs import sinks as obs_sinks
        obs_sinks.bench_json(json_path, result)

    # hard invariants on every host
    np.testing.assert_allclose(
        np.asarray(r4_shard.history["round_loss_est"]),
        np.asarray(r4_local.history["round_loss_est"]), atol=1e-5)
    for a, b in zip(jax.tree.leaves(r4_shard.params),
                    jax.tree.leaves(r4_local.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    assert finite, "non-finite KKT residuals"
    assert alg4_wins, (
        f"algorithm4 final KKT {finals['algorithm4']:.4g} must beat "
        f"frank_wolfe {finals['frank_wolfe']:.4g} and "
        f"dual_decomp {finals['dual_decomp']:.4g} at equal rounds")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (~1-2 min CPU)")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    _force_devices(args.devices)
    rounds = args.rounds or (300 if args.smoke else 600)
    n = 1500 if args.smoke else 4000
    feature_constrained_bench(rounds=rounds, clients=args.clients, n=n,
                              json_path=args.json)


if __name__ == "__main__":
    main()

"""Beyond-paper extension benchmarks:

  ext1 — multiple local SSCA updates per round (the paper's named future
         direction): rounds-to-target vs E (communication savings).
  ext2 — differential-privacy uploads: accuracy cost of the Gaussian
         mechanism at several ε (the paper's §III-A privacy discussion).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import algorithms, fed
from repro.core.local_updates import algorithm1_local
from repro.core.privacy import DPConfig
from repro.data.synthetic import classification_dataset
from repro.models import mlp


def _problem():
    key = jax.random.PRNGKey(0)
    (z, y, _), (zt, _, labt) = classification_dataset(
        key, n=10_000, num_features=128, num_classes=10, test_n=1000,
        noise=4.0)
    params0 = mlp.init(jax.random.PRNGKey(1), 128, 32, 10)
    data = fed.partition_samples(z, y, 10)
    return z, y, zt, labt, params0, data


def psl(p, z, y):
    return mlp.per_sample_loss(p, z, y)


def ext1_local_updates(target=0.8):
    z, y, zt, labt, params0, data = _problem()
    fl = FLConfig(batch_size=32, a1=0.9, a2=0.5, alpha_rho=0.1,
                  alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5)
    ev = lambda p, s: {"cost": float(mlp.mean_loss(p, z[:4000], y[:4000]))}
    results = {}
    for e in (1, 2, 4, 8):
        r = algorithm1_local(psl, params0, data, fl, 300,
                             jax.random.PRNGKey(2), local_steps=e,
                             eval_fn=ev, eval_every=20)
        cost = np.asarray(r.history["cost"])
        rounds = np.asarray(r.history["round"])
        hit = np.nonzero(cost <= target)[0]
        n = int(rounds[hit[0]]) if len(hit) else -1
        results[e] = n
        print(f"ext1.local_ssca.E{e},0,rounds_to_cost{target}={n};"
              f"final={cost[-1]:.4f}", flush=True)
    # claim: more local steps => fewer communication rounds to target
    # (-1 = target not reached within the horizon => treat as +inf)
    norm = {e: (v if v > 0 else 10**9) for e, v in results.items()}
    assert norm[4] < norm[1] and norm[8] <= norm[4], results
    return results


def ext2_dp_uploads():
    z, y, zt, labt, params0, data = _problem()
    fl = FLConfig(batch_size=32, a1=0.9, a2=0.5, alpha_rho=0.1,
                  alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5)

    def run_dp(eps, rounds=200):
        # the first-class dp= stage (DESIGN.md §15): same scan driver as the
        # non-private run, clip+noise inside the round, accountant streamed
        dp = DPConfig(clip_norm=5.0, epsilon=eps, delta=1e-5)
        r = algorithms.algorithm1(psl, params0, data, fl, rounds,
                                  jax.random.PRNGKey(3), dp=dp)
        return (float(mlp.mean_loss(r.params, z[:4000], y[:4000])),
                float(mlp.accuracy(r.params, zt, labt)))

    base = None
    for eps in (float("inf"), 16.0, 4.0, 1.0):
        if eps == float("inf"):
            r = algorithms.algorithm1(psl, params0, data, fl, 200,
                                      jax.random.PRNGKey(3),
                                      eval_fn=lambda p, s: {
                                          "cost": float(mlp.mean_loss(
                                              p, z[:4000], y[:4000])),
                                          "acc": float(mlp.accuracy(p, zt, labt))},
                                      eval_every=200)
            cost = float(r.history["cost"][-1])
            acc = float(r.history["acc"][-1])
        else:
            cost, acc = run_dp(eps)
        if base is None:
            base = cost
        print(f"ext2.dp.eps{eps},0,cost={cost:.4f};acc={acc:.4f}", flush=True)
    # tighter ε must not *improve* the cost (noise only hurts)
    return True

"""Scan-compiled vs per-round-dispatch federated driver benchmark.

Claim (DESIGN.md §6): folding K SSCA rounds into one lax.scan dispatch makes
the hot path faster than the seed's Python round loop, because K host→device
round-trips (and K schedule/pytree re-traversals) collapse into one XLA
program. Prints ``name,us_per_call,derived`` CSV rows like the other benches
and claim-checks both (a) trajectory equality (atol 1e-5) and (b) scan >=
loop rounds/second.
"""
import time

import jax
import numpy as np


def _problem(n=4000, p=64, j=32, l=10, clients=10, batch=50):
    from repro.configs.base import FLConfig
    from repro.core import algorithms, fed
    from repro.data.synthetic import classification_dataset
    from repro.models import mlp

    key = jax.random.PRNGKey(0)
    (z, y, _), _ = classification_dataset(key, n=n, num_features=p,
                                          num_classes=l, test_n=100)
    data = fed.partition_samples(z, y, clients)
    params0 = mlp.init(jax.random.PRNGKey(1), p, j, l)
    fl = FLConfig(num_clients=clients, batch_size=batch, tau=0.2)
    step = algorithms.make_algorithm1_step(mlp.per_sample_loss, data, fl)
    state0 = algorithms.optimizer.ssca_init(params0)
    return step, state0, fl


def rounds_scan_vs_loop(rounds: int = 300, repeats: int = 3):
    from repro.core import rounds as rounds_lib

    step, state0, fl = _problem()
    inputs = rounds_lib.make_inputs(fl, 1, rounds, jax.random.PRNGKey(2))

    def run(engine):
        # warmup/compile
        s, m = engine(step, state0, inputs)
        jax.block_until_ready(s.params)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            s, m = engine(step, state0, inputs)
            jax.block_until_ready(s.params)
            best = min(best, time.perf_counter() - t0)
        return s, m, best

    s_scan, m_scan, t_scan = run(rounds_lib.scan_rounds)
    s_loop, m_loop, t_loop = run(rounds_lib.loop_rounds)

    for name, t in (("scan", t_scan), ("loop", t_loop)):
        print(f"rounds_driver_{name},{1e6 * t / rounds:.1f},"
              f"rounds_per_s={rounds / t:.1f}", flush=True)
    print(f"rounds_driver_speedup,0,scan_over_loop={t_loop / t_scan:.2f}x",
          flush=True)

    np.testing.assert_allclose(np.asarray(m_scan["loss_est"]),
                               np.asarray(m_loop["loss_est"]), atol=1e-5)
    for a, b in zip(jax.tree.leaves(s_scan.params), jax.tree.leaves(s_loop.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    assert t_scan < t_loop, (
        f"scan driver ({rounds / t_scan:.1f} rps) not faster than per-round "
        f"dispatch ({rounds / t_loop:.1f} rps)")


if __name__ == "__main__":
    rounds_scan_vs_loop()

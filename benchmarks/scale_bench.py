"""Cohort-engine scaling: round throughput independent of population size
(DESIGN.md §14).

The O(S) engine's claim is that per-round cost depends only on the cohort
size S, never on the population size I: the Feistel draw touches S ids, the
virtual data view synthesizes S shards, and (without a codec) no (I, ...)
array exists anywhere in the round. This bench pins that claim two ways:

  * **flatness sweep** — the same Algorithm-1 cohort chain (S = 256, small
    MLP, scan-compiled K-round dispatch) over I in {1e3, 1e4, 1e5, 1e6};
    rounds/second at every I must sit within 10% of the I = 1e3 baseline
    (interleaved best-of-N timing, compile excluded; see _make_runner for
    why the repeats are round-robined across the sweep). The sweep deliberately runs the
    codec-free path: int8+EF keeps an (I, P) EFStore backing outside the
    round (inherent persistent state, documented in §14), which is exactly
    what the sweep must NOT accidentally time.
  * **trajectory equality** — at small I the O(S) engine must reproduce the
    dense masked engine (atol 1e-5) for every sample-based driver: alg1,
    alg2, alg2_general, sample_sgd, each composed with int8+EF, plus the
    int8+EF+sharded-topology composition.

Prints ``name,us_per_call,derived`` CSV rows like the other benches and
writes the result to JSON (``BENCH_scale.json`` in CI). ``--maxrss`` prints
a final ``MAXRSS_KB=<n>`` line so CI can assert peak memory is independent
of I across subprocess runs.

Usage:  PYTHONPATH=src python -m benchmarks.scale_bench [--smoke]
            [--participation 256] [--rounds 64] [--json BENCH_scale.json]
            [--maxrss] [--skip-traj]
"""
import argparse
import time

FULL_SWEEP = (1_000, 10_000, 100_000, 1_000_000)
SMOKE_SWEEP = (1_000, 10_000, 100_000)


def _make_runner(clients, participation, rounds, batch=16,
                 features=32, classes=4, hidden=16):
    """Build + compile one timed cohort chain; returns run() -> seconds.

    The runners for every I are built up front and timed INTERLEAVED
    (round-robin over the sweep) so host-level drift — thermal throttling,
    noisy-neighbor CPU on shared runners — hits every population size
    equally instead of biasing whichever I happened to run last."""
    import jax

    from repro.configs.base import FLConfig
    from repro.core import algorithms, optimizer
    from repro.core import rounds as rounds_lib
    from repro.data.synthetic import VirtualFedData
    from repro.models import mlp

    data = VirtualFedData(jax.random.fold_in(jax.random.PRNGKey(0), clients),
                          clients, num_features=features,
                          num_classes=classes, noise=4.0)
    params0 = mlp.init(jax.random.PRNGKey(1), features, hidden, classes)
    fl = FLConfig(batch_size=batch, a1=0.3, a2=0.3, alpha_rho=0.1,
                  alpha_gamma=0.6, tau=0.05, l2_lambda=1e-5)
    step = algorithms.make_algorithm1_step(mlp.per_sample_loss, data, fl,
                                           participation=participation,
                                           cohort=True)
    inputs = rounds_lib.make_inputs(fl, 1, rounds, jax.random.PRNGKey(2))
    state0 = optimizer.ssca_init(params0)

    s, _ = rounds_lib.scan_rounds(step, state0, inputs)     # compile + warm
    jax.block_until_ready(s.params)

    def run():
        t0 = time.perf_counter()
        out, _ = rounds_lib.scan_rounds(step, state0, inputs)
        jax.block_until_ready(out.params)
        return time.perf_counter() - t0

    return run


def _trajectory_diffs(clients=48, participation=12, rounds=10):
    """Dense engine vs O(S) engine, every sample-based driver, int8+EF."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.comm.codecs import make_codec
    from repro.configs.base import FLConfig
    from repro.core import algorithms, baselines
    from repro.core import topology as topology_lib
    from repro.data.synthetic import VirtualFedData
    from repro.models import mlp

    P, J, L = 10, 8, 3
    key = jax.random.PRNGKey(31)
    vd = VirtualFedData(jax.random.fold_in(key, 1), clients, n_min=6,
                        n_max=14, num_features=P, num_classes=L)
    dense = vd.materialize()
    params0 = mlp.init(jax.random.fold_in(key, 2), P, J, L)
    rk = jax.random.fold_in(key, 3)
    fl = FLConfig(batch_size=6, a1=0.9, a2=0.5, alpha_rho=0.1,
                  alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5)
    flc = FLConfig(batch_size=6, a1=0.9, a2=0.5, alpha_rho=0.1,
                   alpha_gamma=0.6, tau=0.2, l2_lambda=1e-5,
                   constrained=True, cost_limit=1.2, penalty_c=1e4)
    codec = make_codec("int8")
    kw = dict(participation=participation, codec=codec)

    def maxdiff(a, b):
        return max(float(jnp.max(jnp.abs(x - y))) for x, y in
                   zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)))

    diffs = {}
    diffs["alg1_int8"] = maxdiff(
        algorithms.algorithm1(mlp.per_sample_loss, params0, dense, fl,
                              rounds, rk, **kw),
        algorithms.algorithm1(mlp.per_sample_loss, params0, vd, fl,
                              rounds, rk, cohort=True, **kw))
    diffs["alg2_int8"] = maxdiff(
        algorithms.algorithm2(mlp.per_sample_loss, params0, dense, flc,
                              rounds, rk, **kw),
        algorithms.algorithm2(mlp.per_sample_loss, params0, vd, flc,
                              rounds, rk, cohort=True, **kw))
    diffs["alg2_general_int8"] = maxdiff(
        algorithms.algorithm2_general(mlp.per_sample_loss,
                                      mlp.per_sample_loss, params0, dense,
                                      flc, rounds, rk, **kw),
        algorithms.algorithm2_general(mlp.per_sample_loss,
                                      mlp.per_sample_loss, params0, vd,
                                      flc, rounds, rk, cohort=True, **kw))
    cfg = baselines.SGDConfig(local_steps=2, local_batch=4)
    diffs["sample_sgd_int8"] = maxdiff(
        baselines.sample_sgd(mlp.per_sample_loss, params0, dense, cfg,
                             rounds, rk, **kw),
        baselines.sample_sgd(mlp.per_sample_loss, params0, vd, cfg,
                             rounds, rk, cohort=True, **kw))
    # the full composition: O(S) engine + int8 + EF + sharded cohort axis
    diffs["alg1_int8_sharded"] = maxdiff(
        algorithms.algorithm1(mlp.per_sample_loss, params0, dense, fl,
                              rounds, rk, **kw),
        algorithms.algorithm1(mlp.per_sample_loss, params0, vd, fl,
                              rounds, rk, cohort=True,
                              topology=topology_lib.sharded_for(
                                  participation), **kw))
    assert np.isfinite(list(diffs.values())).all()
    return diffs


def scale_sweep(clients_list=FULL_SWEEP, participation: int = 256,
                rounds: int = 96, repeats: int = 6, traj: bool = True,
                json_path: str = None, flat_tol: float = 0.10):
    runners = [(c, _make_runner(c, participation, rounds))
               for c in clients_list]
    best = {c: float("inf") for c in clients_list}
    for _ in range(repeats):                    # interleaved: drift-immune
        for c, run in runners:
            best[c] = min(best[c], run())

    sweep = []
    base_rps = None
    for clients in clients_list:
        rps = rounds / best[clients]
        if base_rps is None:
            base_rps = rps
        ratio = rps / base_rps
        sweep.append({"clients": clients, "rounds_per_s": rps,
                      "ratio_vs_smallest": ratio})
        print(f"scale_cohort_I{clients},{1e6 / rps:.1f},"
              f"rounds_per_s={rps:.1f},ratio={ratio:.3f}", flush=True)

    worst = max(abs(row["ratio_vs_smallest"] - 1.0) for row in sweep)
    flat_ok = worst <= flat_tol
    result = {
        "participation": participation, "rounds": rounds, "repeats": repeats,
        "sweep": sweep, "max_throughput_deviation": worst,
        "flatness_claim": "pass" if flat_ok else "fail",
        "flat_tol": flat_tol,
    }
    print(f"scale_cohort_flatness,0,max_deviation={worst:.3f},"
          f"claim={result['flatness_claim']}", flush=True)

    if traj:
        diffs = _trajectory_diffs()
        traj_worst = max(diffs.values())
        result["trajectory_max_abs_diff"] = diffs
        result["trajectory_claim"] = "pass" if traj_worst < 1e-5 else "fail"
        for name, d in diffs.items():
            print(f"scale_traj_{name},0,max_abs_diff={d:.2e}", flush=True)
        print(f"scale_traj_equality,0,worst={traj_worst:.2e},"
              f"claim={result['trajectory_claim']}", flush=True)

    if json_path:
        from repro.obs import sinks as obs_sinks
        obs_sinks.bench_json(json_path, result)

    # trajectory equality is the hard invariant on every host
    if traj:
        assert traj_worst < 1e-5, (
            f"O(S) cohort engine diverged from the dense engine: {diffs}")
    assert flat_ok, (
        f"rounds/sec not flat in population size: worst deviation {worst:.3f}"
        f" > {flat_tol} across {[r['clients'] for r in sweep]} "
        f"({[round(r['rounds_per_s'], 1) for r in sweep]} rounds/s)")
    return result


def scale_smoke():
    """CI/run.py entry: I up to 1e5, S = 64, ~1-2 min on a laptop CPU."""
    return scale_sweep(clients_list=SMOKE_SWEEP, participation=64,
                       rounds=96, repeats=6)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: I <= 1e5, S = 64")
    ap.add_argument("--participation", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--repeats", type=int, default=6)
    ap.add_argument("--clients", type=int, nargs="+", default=None,
                    help="population sizes to sweep (overrides --smoke list)")
    ap.add_argument("--json", default=None)
    ap.add_argument("--skip-traj", action="store_true")
    ap.add_argument("--maxrss", action="store_true",
                    help="print MAXRSS_KB=<peak rss> on exit (CI memory-"
                         "independence probe)")
    args = ap.parse_args()
    clients_list = tuple(args.clients) if args.clients else (
        SMOKE_SWEEP if args.smoke else FULL_SWEEP)
    participation = args.participation or (64 if args.smoke else 256)
    rounds = args.rounds or 96
    try:
        scale_sweep(clients_list=clients_list, participation=participation,
                    rounds=rounds, repeats=args.repeats,
                    traj=not args.skip_traj, json_path=args.json)
    finally:
        if args.maxrss:
            import resource
            print(f"MAXRSS_KB="
                  f"{resource.getrusage(resource.RUSAGE_SELF).ru_maxrss}",
                  flush=True)


if __name__ == "__main__":
    main()
